"""Model definitions."""
