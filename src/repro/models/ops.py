"""Shared model ops: norms, rotary embeddings, attention (direct + chunked).

The chunked attention path is the XLA-portable flash analogue (scan over
query chunks, online statistics not needed because each chunk sees all keys
at once but never materialises the full S_q x S_k score tensor).  The Pallas
kernel in ``repro.kernels.flash_attention`` is the TPU-optimised version of
the same contraction and is validated against ``attention_reference``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    """Execution context: activation-sharding constraints + kernel
    implementation selection.  ``enabled=False`` (smoke tests, single
    device) turns every sharding constraint into a no-op.

    ``attention_impl`` / ``ssm_impl``: "xla" (portable chunked paths,
    the dry-run/compile default — Pallas/Mosaic does not lower on the CPU
    backend) or "pallas" (the TPU kernels in ``repro.kernels``, run in
    interpret mode off-TPU)."""

    enabled: bool = False
    dp: Tuple[str, ...] = ("data",)       # batch axes
    tp: Optional[str] = "model"
    heads_sharded: bool = True
    ff_sharded: bool = True
    attention_impl: str = "xla"
    ssm_impl: str = "xla"
    # Sequence-parallel attention: when the head count does not divide the
    # model axis (qwen2: 12, whisper: 20 vs 16), attention would otherwise
    # run fully REPLICATED on that axis.  This shards q (and the score /
    # output tensors) over the model axis on the SEQUENCE dim instead —
    # k/v stay replicated (they are small under GQA) — so attention
    # compute and its S^2 buffers split 16-ways.  §Perf hillclimb flag.
    seq_parallel_attn: bool = False
    # Recompute per-chunk attention in the backward pass instead of
    # stacking per-chunk softmax residuals (an S^2-sized buffer) between
    # the rematted forward and the scan transpose.  §Perf hillclimb flag.
    remat_chunk_attn: bool = False
    # Row-local MoE dispatch (scatters vmapped over the batch dim stay on
    # the data shard; no replicated (T, d) combine buffer).  §Perf flag.
    moe_row_dispatch: bool = False
    # Megatron-style sequence parallelism for the residual stream: the
    # layer carry (and its remat-saved copy) is sharded over the model
    # axis on the SEQ dim.  Shrinks the stacked-activation footprint by
    # the TP degree and turns the TP partial-sum all-reduces into
    # reduce-scatter (+ all-gather at the next consumer) = half the
    # collective bytes.  §Perf hillclimb flag.
    seq_parallel_residual: bool = False

    def act(self, x: jax.Array, *axes) -> jax.Array:
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, P(*axes))

    def batch(self, x: jax.Array) -> jax.Array:
        """Constrain leading axis to the data-parallel axes only."""
        return self.act(x, self.dp, *([None] * (x.ndim - 1)))

    def res(self, x: jax.Array) -> jax.Array:
        """Residual-stream constraint for a (B, S, d) carry.  Seq-shards
        only full sequences (decode carries have S == 1)."""
        if self.seq_parallel_residual and self.tp is not None \
                and x.ndim >= 3 and x.shape[1] % 128 == 0:
            return self.act(x, self.dp, self.tp, *([None] * (x.ndim - 2)))
        return self.batch(x)

    @property
    def heads(self):
        return self.tp if self.heads_sharded else None


NOSHARD = ShardCtx(enabled=False)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Interleaved (NeoX pair) rotary embedding.

    Interleaved pairs (2i, 2i+1) keep each rotation local to its pair, so a
    head-dim-sharded tensor (decode path) needs no cross-shard shuffle as
    long as shards are even-sized.

    x: (..., S, n_heads, hd); positions: (..., S) absolute positions.
    """
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x2 = x.reshape(*x.shape[:-1], hd // 2, 2)
    x_even, x_odd = x2[..., 0], x2[..., 1]
    out = jnp.stack(
        [x_even * cos - x_odd * sin, x_even * sin + x_odd * cos], axis=-1
    )
    return out.reshape(x.shape).astype(x.dtype)


def attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Plain softmax attention with GQA head grouping (the oracle).

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).  H must be a multiple of KV.
    ``q_offset``: absolute position of q[0] (for causal masking in decode).
    ``kv_len``: optional dynamic number of valid kv entries (cache decode);
    a scalar, or a (B,) vector for continuous-batching decode where every
    slot sits at its own sequence position.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    mask = None  # broadcastable to (B, 1, 1, Sq, Sk)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Sk)
        mask = (qpos[:, None] >= kpos[None, :])[None, None, None]
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        valid = jnp.arange(Sk)[None, :] < jnp.atleast_1d(kv_len)[:, None]
        valid = valid[:, None, None, None, :]       # (B|1, 1, 1, 1, Sk)
        mask = valid if mask is None else mask & valid
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_chunk: int = 512,
    remat_body: bool = False,
) -> jax.Array:
    """Query-chunked attention: O(q_chunk * Sk) live scores.

    Matches attention_reference exactly (same math, chunked q loop).
    ``remat_body`` recomputes each chunk's scores in the backward pass, so
    the scan saves NO per-chunk softmax residuals (which would otherwise
    stack into a full S^2 tensor between the forward and the transpose).
    """
    B, Sq, H, hd = q.shape
    if Sq <= q_chunk:
        return attention_reference(q, k, v, causal=causal)
    assert Sq % q_chunk == 0, (Sq, q_chunk)
    n = Sq // q_chunk
    qs = q.reshape(B, n, q_chunk, H, hd).swapaxes(0, 1)  # (n, B, qc, H, hd)

    def chunk(i, qc, k_, v_):
        return attention_reference(qc, k_, v_, causal=causal,
                                   q_offset=i * q_chunk)

    if remat_body:
        chunk = jax.checkpoint(
            chunk, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(),
        )

    def body(_, args):
        i, qc = args
        return None, chunk(i, qc, k, v)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qs))
    return outs.swapaxes(0, 1).reshape(B, Sq, H, hd)


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, vocab: int
) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over tokens + z-loss term; logits (..., Vp) may be padded to
    Vp >= vocab — padded slots are masked out of the partition function."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab:
        pad_mask = jnp.arange(vp) >= vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    zloss = jnp.square(logz).mean()
    return ce, zloss
