"""Architecture and shape configuration for the assigned model pool.

Every assigned architecture is a selectable config (``--arch <id>``); every
(arch x shape) cell is well-defined through ``Cell``.  Configs are exact to
the assignment table; sharding-driven padding (vocab to multiples of 256)
is recorded separately so the logical vocab is preserved for the loss.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"          # decoder-only full attention
    MOE = "moe"              # decoder-only with MoE MLP
    SSM = "ssm"              # pure mamba1
    HYBRID = "hybrid"        # mamba2 backbone + shared attention blocks
    ENC_DEC = "enc_dec"      # whisper-style encoder-decoder
    VLM = "vlm"              # decoder-only w/ vision-patch stub frontend
    AUDIO = "audio"          # alias for enc-dec with audio stub frontend


class MLPKind(str, enum.Enum):
    GATED_SILU = "gated_silu"    # llama-style SwiGLU
    GELU = "gelu"                # plain 2-matrix GELU (whisper)
    RELU2 = "relu2"              # squared-ReLU (nemotron)


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Experts padded so the expert axis is shardable over the model axis.
    n_experts_padded: int = 0

    def __post_init__(self):
        if self.n_experts_padded == 0:
            object.__setattr__(
                self, "n_experts_padded", self.n_experts
            )


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # mamba2 only:
    head_dim: int = 64
    chunk: int = 256
    version: int = 1   # 1 = mamba1 selective scan, 2 = mamba2 SSD


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    mlp: MLPKind = MLPKind.GATED_SILU
    head_dim: Optional[int] = None       # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: one shared attention block applied every `shared_attn_period`
    # backbone layers (zamba2-style).
    shared_attn_period: int = 0
    # enc-dec: encoder length used by serving/training cells.
    enc_len: int = 0
    # Modality frontend stub: inputs are precomputed embeddings of this dim.
    frontend_stub: Optional[str] = None  # "audio" | "vision" | None
    norm_eps: float = 1e-5
    # Whether the arch supports 500k contexts (sub-quadratic path).
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return pad_to(self.vocab, 256)

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    def param_count(self) -> int:
        """Approximate parameter count N (for 6ND model FLOPs)."""
        L, d, V = self.n_layers, self.d_model, self.vocab_padded
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.mlp == MLPKind.GATED_SILU:
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family in (Family.DENSE, Family.VLM):
            total += L * (attn + mlp)
        elif self.family == Family.MOE:
            assert self.moe
            total += L * (attn + self.moe.n_experts * mlp + d * self.moe.n_experts)
        elif self.family == Family.SSM:
            di, n = self.d_inner, self.ssm.d_state
            # in_proj (x,z), conv, dt/B/C projections, A, D, out_proj
            per = d * 2 * di + di * self.ssm.d_conv + di * (2 * n + di // 16) \
                + di * n + 2 * di + di * d
            total += L * per
        elif self.family == Family.HYBRID:
            # zamba2: mamba2 backbone layers (no per-layer MLP) + ONE shared
            # attention+MLP block applied every shared_attn_period layers.
            di, n = self.d_inner, self.ssm.d_state
            nh = di // self.ssm.head_dim
            per = d * (2 * di + 2 * n + nh) + di * self.ssm.d_conv + di * d
            total += L * per
            total += attn + 3 * d * self.d_ff  # shared block (attn + SwiGLU)
        elif self.family in (Family.ENC_DEC, Family.AUDIO):
            total += L * (attn + mlp)            # decoder self-attn + mlp
            total += L * attn                    # decoder cross-attn
            total += L * (attn + mlp)            # encoder
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if self.family != Family.MOE:
            return self.param_count()
        assert self.moe
        dense_like = dataclasses.replace(self, family=Family.DENSE, moe=None)
        base = dense_like.param_count()
        # replace the dense MLP with top_k experts
        L, d = self.n_layers, self.d_model
        mlp = (3 if self.mlp == MLPKind.GATED_SILU else 2) * d * self.d_ff
        return base - L * mlp + L * (self.moe.top_k * mlp + d * self.moe.n_experts)


# ---------------------------------------------------------------------------
# Shapes (per assignment: all LM shapes are seq_len x global_batch)
# ---------------------------------------------------------------------------


class Kind(str, enum.Enum):
    TRAIN = "train"
    PREFILL = "prefill"
    DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: Kind
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", Kind.TRAIN, 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", Kind.PREFILL, 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", Kind.DECODE, 32_768, 128),
    "long_500k": ShapeConfig("long_500k", Kind.DECODE, 524_288, 1),
}


@dataclass(frozen=True)
class CellTuning:
    """Per-(arch x shape) execution tuning (microbatching, remat, dtypes)."""

    num_microbatches: int = 1
    remat: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    accum_dtype: str = "float32"     # gradient-accumulation buffer dtype
    # Kernel implementation: "xla" (portable; the dry-run default — Pallas
    # does not lower on the CPU backend) or "pallas" (TPU kernels).
    attention_impl: str = "xla"
    ssm_impl: str = "xla"
    # §Perf hillclimb flags (default off = paper-faithful baseline):
    seq_parallel_attn: bool = False   # seq-shard attention when heads don't divide
    remat_chunk_attn: bool = False    # recompute chunk scores in backward
    moe_row_dispatch: bool = False    # batch-local MoE dispatch/combine
    seq_parallel_residual: bool = False  # seq-shard the residual stream


def cell_tuning(arch: "ArchConfig", shape: ShapeConfig) -> CellTuning:
    if shape.kind != Kind.TRAIN:
        return CellTuning(num_microbatches=1, remat=False)
    big = arch.param_count() > 30e9
    # 8 microbatches: micro-batch (32 rows) still shards over the 32-way
    # (pod x data) batch axes of the multi-pod mesh.
    return CellTuning(
        num_microbatches=8,
        remat=True,
        opt_state_dtype="bfloat16" if big else "float32",
        accum_dtype="bfloat16" if big else "float32",
    )


def cell_is_supported(arch: "ArchConfig", shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether the (arch x shape) cell runs, with the reason when skipped."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{arch.name} is pure full-attention (skip mandated by assignment)"
        )
    return True, ""
