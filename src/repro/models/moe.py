"""Mixture-of-Experts MLP with sort-based (MegaBlocks-style) dispatch.

Design notes (TPU adaptation):
  * dispatch/combine are gather/scatter ops (bytes, not FLOPs) — the naive
    one-hot-einsum dispatch would dominate the compiled FLOP count and wreck
    the useful-FLOPs ratio in the roofline analysis;
  * experts live in a fixed-capacity buffer (E, C, d) so all shapes are
    static; tokens beyond capacity are dropped (standard capacity-factor
    semantics) and their residual passes through;
  * the expert axis shards over the ``model`` mesh axis (expert parallelism);
    GSPMD inserts the token all-to-all at the data<->expert resharding point;
  * experts may be padded (granite: 40 -> 48) so E divides the model axis;
    padded experts are masked out of the router softmax.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig, MLPKind
from .ops import ShardCtx, rms_norm


def moe_mlp(
    p: Dict, x: jax.Array, cfg: ArchConfig, ctx: ShardCtx
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (B, S, d), aux-loss dict.  Pre-norm block: the
    residual stream is rms-normed before the router and experts see it.

    Two dispatch layouts:
      * global sort (baseline): one token pool of T = B*S slots.  Simple,
        but the combine scatter over the flattened pool cannot be sharded
        by GSPMD — it replicates a (T, d) f32 buffer on every model-axis
        device and all-reduces it per layer (the dominant collective cost
        of MoE training cells).
      * row dispatch (ctx.moe_row_dispatch, §Perf): vmap the sort
        dispatch/combine over the BATCH dim.  Scatters/gathers then have
        a data-sharded batch dim, so they stay local to the data shard;
        only the compact (B, E, C_row, d) expert buffers cross the model
        axis.  Same routing semantics per token (capacity is per row).
    """
    if ctx.moe_row_dispatch:
        return _moe_mlp_rows(p, x, cfg, ctx)
    moe = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, Ep, k = moe.n_experts, moe.n_experts_padded, moe.top_k
    C = int(-(-T * k // Ep) * moe.capacity_factor)  # ceil(T*k/Ep)*cf
    C = max(8, C)

    xf = rms_norm(x, p["ln"], cfg.norm_eps).reshape(T, d)
    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, Ep)
    if Ep > E:
        pad_mask = jnp.arange(Ep) >= E
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch ------------------------------------------------
    e_flat = idx.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(e_flat)                              # stable
    e_sorted = e_flat[order]
    tok_sorted = order // k
    counts = jnp.sum(
        jax.nn.one_hot(e_flat, Ep, dtype=jnp.int32), axis=0
    )                                                        # (Ep,)
    offsets = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k, dtype=jnp.int32) - offsets[e_sorted]
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)

    buf = jnp.zeros((Ep, C, d), x.dtype)
    buf = buf.at[e_sorted, pos_c].add(
        jnp.where(keep[:, None], xf[tok_sorted], 0.0)
    )
    buf = ctx.act(buf, ctx.tp, None, None)                   # EP shard

    # --- expert computation (batched over experts) --------------------------
    if cfg.mlp == MLPKind.GATED_SILU:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_buf = ctx.act(out_buf, ctx.tp, None, None)

    # --- combine ------------------------------------------------------------
    gathered = out_buf[e_sorted, pos_c]                      # (T*k, d)
    g_sorted = gates.reshape(-1)[order]
    contrib = jnp.where(keep[:, None], gathered * g_sorted[:, None], 0.0)
    yf = jnp.zeros((T, d), x.dtype).at[tok_sorted].add(contrib)

    # --- aux losses (load balance + router z-loss) ---------------------------
    # fraction of tokens routed to each expert (top-1 assignment share)
    me = jnp.mean(jax.nn.one_hot(idx[:, 0], Ep, dtype=jnp.float32), axis=0)
    pe = jnp.mean(probs, axis=0)
    load_balance = Ep * jnp.sum(me * pe)
    z = jax.nn.logsumexp(logits, axis=-1)
    router_z = jnp.mean(jnp.square(z))
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return yf.reshape(B, S, d), {
        "load_balance": load_balance,
        "router_z": router_z,
        "drop_fraction": drop_frac,
    }


def _moe_mlp_rows(
    p: Dict, x: jax.Array, cfg: ArchConfig, ctx: ShardCtx
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Row-dispatched MoE (§Perf): scatters/gathers vmapped over the
    batch dim so they stay local to the data shard."""
    moe = cfg.moe
    B, S, d = x.shape
    E, Ep, k = moe.n_experts, moe.n_experts_padded, moe.top_k
    # per-row capacity, padded to a lane-friendly multiple of 8
    C = int(-(-S * k // Ep) * moe.capacity_factor)
    C = max(8, (C + 7) // 8 * 8)

    xn = rms_norm(x, p["ln"], cfg.norm_eps)               # (B, S, d)
    logits = (xn @ p["router"]).astype(jnp.float32)       # (B, S, Ep)
    if Ep > E:
        pad_mask = jnp.arange(Ep) >= E
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                  # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    def dispatch_row(xr, er):
        """xr: (S, d); er: (S, k) -> buf (Ep, C, d), routing metadata."""
        e_flat = er.reshape(-1)                           # (S*k,)
        order = jnp.argsort(e_flat)
        e_sorted = e_flat[order]
        tok_sorted = order // k
        counts = jnp.sum(jax.nn.one_hot(e_flat, Ep, dtype=jnp.int32), axis=0)
        offsets = jnp.cumsum(counts) - counts
        pos = jnp.arange(S * k, dtype=jnp.int32) - offsets[e_sorted]
        keep = pos < C
        pos_c = jnp.where(keep, pos, 0)
        buf = jnp.zeros((Ep, C, d), xr.dtype)
        buf = buf.at[e_sorted, pos_c].add(
            jnp.where(keep[:, None], xr[tok_sorted], 0.0))
        # token-order routing tables for the scatter-free combine
        inv = jnp.zeros_like(order).at[order].set(
            jnp.arange(order.shape[0], dtype=order.dtype))
        return buf, (e_flat, pos_c[inv], keep[inv])

    buf, meta = jax.vmap(dispatch_row)(xn, idx)           # (B, Ep, C, d)
    buf = ctx.act(buf, ctx.dp, ctx.tp, None, None)        # B:data, E:model

    if cfg.mlp == MLPKind.GATED_SILU:
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
            * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, p["w_up"]))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    out_buf = ctx.act(out_buf, ctx.dp, ctx.tp, None, None)

    def combine_row(ob, gr, m):
        """Scatter-free combine: gather the k expert outputs per token and
        reduce over k.  The sum sits directly above any partial-gather
        all-reduce GSPMD inserts for the E-sharded ``ob``, so XLA can
        reassociate the collective to (S, d) instead of (S*k, d)."""
        e_tok, pos_tok, keep_tok = m
        gathered = ob[e_tok, pos_tok]                     # (S*k, d)
        contrib = jnp.where(keep_tok[:, None],
                            gathered * gr.reshape(-1)[:, None], 0.0)
        return contrib.reshape(S, k, d).sum(axis=1)

    y = jax.vmap(combine_row)(out_buf, gates.astype(out_buf.dtype), meta)
    y = ctx.act(y, ctx.dp, None, None)

    me = jnp.mean(jax.nn.one_hot(idx[..., 0], Ep, dtype=jnp.float32),
                  axis=(0, 1))
    pe = jnp.mean(probs, axis=(0, 1))
    load_balance = Ep * jnp.sum(me * pe)
    z = jax.nn.logsumexp(logits, axis=-1)
    router_z = jnp.mean(jnp.square(z))
    keep_all = meta[2]
    drop_frac = 1.0 - jnp.mean(keep_all.astype(jnp.float32))
    return y, {
        "load_balance": load_balance,
        "router_z": router_z,
        "drop_fraction": drop_frac,
    }
