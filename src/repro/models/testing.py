"""Reduced same-family configs for CPU smoke tests.

Full configs are only ever exercised via the dry-run (ShapeDtypeStruct, no
allocation); everything numeric runs on these shrunken twins.
"""
from __future__ import annotations

import dataclasses

from .config import ArchConfig, MoEConfig, SSMConfig


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Shrink an architecture, preserving family and structural quirks."""
    kw = dict(
        n_layers=4 if cfg.shared_attn_period == 0 else 5,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab=257,   # deliberately not a multiple of 256 -> exercises padding
        head_dim=16,
    )
    if cfg.moe is not None:
        # capacity_factor 4.0: no capacity drops at smoke-test batch sizes,
        # keeping decode-vs-full-forward consistency exact (drops are
        # batch-shape dependent by design).
        kw["moe"] = MoEConfig(
            n_experts=4, top_k=min(cfg.moe.top_k, 2), n_experts_padded=4,
            capacity_factor=4.0,
        )
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(
            d_state=8, d_conv=4, expand=2, head_dim=16,
            chunk=8, version=cfg.ssm.version,
        )
    if cfg.shared_attn_period:
        kw["shared_attn_period"] = 2   # 5 layers -> 2 shared applications + 1
    if cfg.enc_len:
        kw["enc_len"] = 16
    return dataclasses.replace(cfg, **kw)
