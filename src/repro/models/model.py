"""Model forwards for all assigned architecture families.

Three modes share one code path per family:
  * train    — full-sequence forward, no cache;
  * prefill  — full-sequence forward EMITTING a KV/state cache;
  * decode   — one-token step consuming/updating the cache (serve_step).

Layers are stacked along a leading L axis and executed with ``jax.lax.scan``
so HLO size and compile time are O(1) in depth (mandatory at 96 layers /
18432 width).  Caches are pytrees whose leaves carry the same leading L axis
and travel through the scan as xs/ys.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig, Family, MLPKind
from .moe import moe_mlp
from .ops import (
    NOSHARD,
    ShardCtx,
    attention_chunked,
    attention_reference,
    rms_norm,
    rotary,
)
from .sharding import ParamSchema as PS
from .ssm import mamba1_block, mamba2_block

Cache = Dict[str, Any]

TRAIN, PREFILL, DECODE = "train", "prefill", "decode"


# ---------------------------------------------------------------------------
# attention / mlp blocks
# ---------------------------------------------------------------------------


def attention_block(
    p: Dict,
    x: jax.Array,
    cfg: ArchConfig,
    ctx: ShardCtx,
    *,
    mode: str,
    causal: bool = True,
    use_rope: bool = True,
    kv_cache: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
    cross_states: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Residual attention block.

    decode: ``kv_cache`` = (k, v, pos), k/v (B, S_max, KV, hd).
    cross-attention: k/v from ``cross_states`` (train/prefill) or from the
    cache (decode).
    Returns (residual output, (k, v) for the cache or None).
    """
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]

    if mode == DECODE and cross_states is None and kv_cache is not None \
            and causal:
        # self-attention decode step; ``pos`` is a scalar (lockstep batch)
        # or a (B,) vector (continuous batching: each slot at its own
        # sequence position)
        kc, vc, pos = kv_cache
        per_slot = jnp.ndim(pos) == 1
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        if cfg.qkv_bias:
            k = k + p["bk"][None, None]
            v = v + p["bv"][None, None]
        if use_rope:
            rope_pos = (pos[:, None] if per_slot else pos) \
                + jnp.arange(q.shape[1])
            q = rotary(q, rope_pos, cfg.rope_theta)
            k = rotary(k, rope_pos, cfg.rope_theta)
        if per_slot:
            b_idx = jnp.arange(kc.shape[0])
            kc = kc.at[b_idx, pos].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[b_idx, pos].set(v[:, 0].astype(vc.dtype))
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k.astype(kc.dtype), pos, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v.astype(vc.dtype), pos, 1)
        kc = ctx.act(kc, ctx.dp, None, None, ctx.tp)  # head-dim sharded
        vc = ctx.act(vc, ctx.dp, None, None, ctx.tp)
        out = attention_reference(
            q, kc, vc, causal=False, kv_len=pos + q.shape[1]
        )
        new_kv = (kc, vc)
    elif mode == DECODE and kv_cache is not None:
        # cross-attention decode: K/V precomputed at prefill
        kc, vc, _ = kv_cache
        out = attention_reference(q, kc, vc, causal=False)
        new_kv = (kc, vc)
    else:
        src = cross_states if cross_states is not None else h
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
        if cfg.qkv_bias:
            k = k + p["bk"][None, None]
            v = v + p["bv"][None, None]
        if use_rope:
            pos = jnp.arange(q.shape[1])
            q = rotary(q, pos, cfg.rope_theta)
            k = rotary(k, jnp.arange(k.shape[1]), cfg.rope_theta)
        seq_par = ctx.seq_parallel_attn and ctx.heads is None \
            and ctx.tp is not None
        if seq_par:
            # heads don't divide the model axis: shard the SEQUENCE dim of
            # q over it (k/v stay replicated — small under GQA), so the
            # attention compute and its S^2 score buffers split instead of
            # replicating across the model axis.
            q = ctx.act(q, ctx.dp, ctx.tp, None, None)
            k = ctx.act(k, ctx.dp, None, None, None)
            v = ctx.act(v, ctx.dp, None, None, None)
        else:
            q = ctx.act(q, ctx.dp, None, ctx.heads, None)
        if ctx.attention_impl == "pallas":
            from repro.kernels.ops import flash_attention

            out = flash_attention(q, k, v, causal=causal).astype(q.dtype)
        elif seq_par:
            # No q-chunk scan: the per-device score slab is already 1/16
            # of S^2 (seq-sharded rows), and a chunked reshape could not
            # express that sharding (512-chunks vs 256-row shards).
            out = attention_reference(q, k, v, causal=causal)
        else:
            out = attention_chunked(q, k, v, causal=causal,
                                    remat_body=ctx.remat_chunk_attn)
        if seq_par:
            out = ctx.act(out, ctx.dp, ctx.tp, None, None)
        new_kv = (k, v)
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return x + proj, new_kv


def mlp_block(p: Dict, x: jax.Array, cfg: ArchConfig, ctx: ShardCtx) -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if cfg.mlp == MLPKind.GATED_SILU:
        u = jax.nn.silu(h @ p["w_gate"]) * (h @ p["w_up"])
    elif cfg.mlp == MLPKind.GELU:
        u = h @ p["w_up"]
        if "b_up" in p:
            u = u + p["b_up"][None, None]
        u = jax.nn.gelu(u)
    else:  # RELU2 (nemotron)
        u = jnp.square(jax.nn.relu(h @ p["w_up"]))
    u = ctx.act(u, ctx.dp, None, ctx.tp if ctx.ff_sharded else None)
    out = u @ p["w_down"]
    if "b_down" in p:
        out = out + p["b_down"][None, None]
    return x + out


# ---------------------------------------------------------------------------
# decoder stacks (scan over layers)
# ---------------------------------------------------------------------------


def _dense_stack(params, h, cfg, ctx, cache, *, mode, remat):
    """DENSE / VLM / MOE decoder."""
    is_moe = cfg.family == Family.MOE
    pos0 = cache["pos"] if cache is not None else jnp.int32(0)

    def layer(h, xs):
        lp, kc, vc = xs
        kv = (kc, vc, pos0) if kc is not None else None
        h, new_kv = attention_block(
            lp["attn"], h, cfg, ctx, mode=mode, kv_cache=kv, causal=True
        )
        aux = {}
        if is_moe:
            y, aux = moe_mlp(lp["moe"], h, cfg, ctx)
            h = h + y
        else:
            h = mlp_block(lp["mlp"], h, cfg, ctx)
        return ctx.res(h), (new_kv, aux)

    if remat:
        layer = jax.checkpoint(layer)

    xs = (params["layers"],
          cache["k"] if cache else None,
          cache["v"] if cache else None)

    emit_kv = mode in (PREFILL, DECODE)

    def body(carry, xs):
        h, (new_kv, aux) = layer(carry, xs)
        return h, ((new_kv if emit_kv else None), aux)

    h, (kvs, auxes) = jax.lax.scan(body, h, xs)
    new_cache = None
    if emit_kv:
        k_s, v_s = kvs
        new_cache = {"k": k_s, "v": v_s,
                     "pos": pos0 + (1 if mode == DECODE else h.shape[1])}
    aux = {k: jnp.mean(v) for k, v in auxes.items()} if auxes else {}
    return h, new_cache, aux


def _ssm_stack(params, h, cfg, ctx, cache, *, mode, remat):
    emit = mode in (PREFILL, DECODE)

    def layer(h, xs):
        lp, cc = xs
        h, new_c = mamba1_block(
            lp, h, cfg, ctx, cache=cc, return_state=emit
        )
        return ctx.res(h), new_c

    if remat:
        layer = jax.checkpoint(layer)

    cc = None
    if cache is not None:
        cc = {"conv": cache["conv"], "ssm": cache["ssm"]}
    h, new_cs = jax.lax.scan(layer, h, (params["layers"], cc))
    new_cache = None
    if emit:
        pos0 = cache["pos"] if cache is not None else jnp.int32(0)
        new_cache = {
            "conv": new_cs["conv"], "ssm": new_cs["ssm"],
            "pos": pos0 + (1 if mode == DECODE else h.shape[1]),
        }
    return h, new_cache, {}


def _hybrid_stack(params, h, cfg, ctx, cache, *, mode, remat):
    """zamba2: mamba2 backbone; a single SHARED attention+MLP block applied
    after every ``shared_attn_period`` layers (own KV cache per
    application point)."""
    L, period = cfg.n_layers, cfg.shared_attn_period
    G = L // period
    emit = mode in (PREFILL, DECODE)
    pos0 = cache["pos"] if cache is not None else jnp.int32(0)

    grouped = jax.tree.map(
        lambda a: a[: G * period].reshape(G, period, *a.shape[1:]),
        params["layers"],
    )
    tail = jax.tree.map(lambda a: a[G * period:], params["layers"])

    def m2_layer(h, xs):
        lp, cc = xs
        h, new_c = mamba2_block(
            lp, h, cfg, ctx, cache=cc, return_state=emit
        )
        return ctx.res(h), new_c

    if remat:
        m2_layer = jax.checkpoint(m2_layer)

    def mamba_slice(sel):
        if cache is None:
            return None
        return {k: sel(cache[k]) for k in ("conv_x", "conv_B", "conv_C", "ssm")}

    def group_body(h, xs):
        gp, gc, kc, vc = xs
        h, new_gc = jax.lax.scan(m2_layer, h, (gp, gc))
        kv = (kc, vc, pos0) if kc is not None else None
        h, new_kv = attention_block(
            params["shared"]["attn"], h, cfg, ctx, mode=mode,
            kv_cache=kv, causal=True,
        )
        h = mlp_block(params["shared"]["mlp"], h, cfg, ctx)
        return ctx.res(h), (new_gc, new_kv if emit else None)

    gxs = (
        grouped,
        mamba_slice(lambda a: a[: G * period].reshape(G, period, *a.shape[1:])),
        cache["shared_k"] if cache else None,
        cache["shared_v"] if cache else None,
    )
    h, (new_gc, new_kvs) = jax.lax.scan(group_body, h, gxs)
    h, new_tc = jax.lax.scan(
        m2_layer, h, (tail, mamba_slice(lambda a: a[G * period:]))
    )

    new_cache = None
    if emit:
        new_cache = {}
        for key in ("conv_x", "conv_B", "conv_C", "ssm"):
            head = new_gc[key].reshape(G * period, *new_gc[key].shape[2:])
            new_cache[key] = jnp.concatenate([head, new_tc[key]], axis=0)
        new_cache["shared_k"], new_cache["shared_v"] = new_kvs
        new_cache["pos"] = pos0 + (1 if mode == DECODE else h.shape[1])
    return h, new_cache, {}


def _encdec_stack(params, h, cfg, ctx, cache, enc_embeds, *, mode, remat):
    """whisper: encoder over stub frame embeddings + causal decoder with
    cross-attention.  decode mode never re-runs the encoder: cross K/V come
    from the cache (filled at prefill)."""
    emit = mode in (PREFILL, DECODE)
    pos0 = cache["pos"] if cache is not None else jnp.int32(0)

    enc_out = None
    if mode in (TRAIN, PREFILL):
        assert enc_embeds is not None, "enc-dec train/prefill needs enc_embeds"
        e = enc_embeds

        def enc_layer(e, lp):
            e, _ = attention_block(
                lp["attn"], e, cfg, ctx, mode=TRAIN, causal=False,
                use_rope=True,
            )
            e = mlp_block(lp["mlp"], e, cfg, ctx)
            return ctx.res(e), None

        if remat:
            enc_layer = jax.checkpoint(enc_layer)
        e, _ = jax.lax.scan(enc_layer, e, params["enc_layers"])
        enc_out = rms_norm(e, params["enc_final_norm"], cfg.norm_eps)

    def dec_layer(h, xs):
        lp, kc, vc, ck, cv = xs
        kv = (kc, vc, pos0) if kc is not None else None
        h, new_kv = attention_block(
            lp["attn"], h, cfg, ctx, mode=mode, kv_cache=kv, causal=True
        )
        if mode == DECODE:
            h, cross_kv = _cross_from_cache(lp["cross"], h, cfg, ctx, ck, cv)
        else:
            h, cross_kv = attention_block(
                lp["cross"], h, cfg, ctx, mode=mode,
                cross_states=enc_out, causal=False, use_rope=False,
            )
        h = mlp_block(lp["mlp"], h, cfg, ctx)
        ys = ((new_kv, cross_kv) if emit else None, {})
        return ctx.res(h), ys

    if remat:
        dec_layer = jax.checkpoint(dec_layer)

    xs = (params["layers"],
          cache["k"] if cache else None, cache["v"] if cache else None,
          cache["cross_k"] if cache else None,
          cache["cross_v"] if cache else None)
    h, (kvs, _) = jax.lax.scan(dec_layer, h, xs)
    new_cache = None
    if emit:
        (k_s, v_s), (ck_s, cv_s) = kvs
        new_cache = {
            "k": k_s, "v": v_s, "cross_k": ck_s, "cross_v": cv_s,
            "pos": pos0 + (1 if mode == DECODE else h.shape[1]),
        }
    return h, new_cache, {}


def _cross_from_cache(p, x, cfg, ctx, ck, cv):
    """Cross-attention against cached encoder K/V (decode path)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]
    out = attention_reference(q, ck, cv, causal=False)
    return x + jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (ck, cv)


# ---------------------------------------------------------------------------
# top-level forward
# ---------------------------------------------------------------------------


def forward(
    params: Dict,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    *,
    ctx: ShardCtx = NOSHARD,
    mode: str = TRAIN,
    cache: Optional[Cache] = None,
    remat: bool = False,
    compute_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Optional[Cache], Dict]:
    """Returns (logits (B, S, Vp), cache (prefill/decode) or None, aux)."""
    assert (cache is not None) == (mode == DECODE), (mode, cache is not None)
    tokens = batch["tokens"]
    p = jax.tree.map(
        lambda a: a.astype(compute_dtype)
        if hasattr(a, "dtype") and a.dtype == jnp.float32 else a,
        params,
    )
    h = jnp.take(p["embed"], tokens, axis=0)
    h = ctx.res(h)

    if cfg.family in (Family.DENSE, Family.VLM, Family.MOE):
        h, new_cache, aux = _dense_stack(
            p, h, cfg, ctx, cache, mode=mode, remat=remat)
    elif cfg.family == Family.SSM:
        h, new_cache, aux = _ssm_stack(
            p, h, cfg, ctx, cache, mode=mode, remat=remat)
    elif cfg.family == Family.HYBRID:
        h, new_cache, aux = _hybrid_stack(
            p, h, cfg, ctx, cache, mode=mode, remat=remat)
    elif cfg.family in (Family.ENC_DEC, Family.AUDIO):
        enc = batch.get("enc_embeds")
        if enc is not None:
            enc = enc.astype(compute_dtype)
        h, new_cache, aux = _encdec_stack(
            p, h, cfg, ctx, cache, enc, mode=mode, remat=remat)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, p["final_norm"], cfg.norm_eps)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = h @ head
    logits = ctx.act(logits, ctx.dp, None, ctx.tp)
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# cache schema (shapes + sharding for decode dry-runs / serving)
# ---------------------------------------------------------------------------


def cache_schema(
    cfg: ArchConfig, batch: int, max_len: int, enc_len: int = 0
) -> Dict:
    """Decode-cache schema; leading L axis matches the scan layout.

    KV caches are head-dim sharded over the model axis (hd is a multiple of
    16 for no assigned arch < 64), which keeps dynamic_update_slice local
    (no resharding on the sequence axis) while splitting cache bytes.
    """
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    kv = lambda s: PS((L, batch, s, KV, hd),
                      ("layers", "batch", "seq", "heads_kv", "hd_cache"),
                      init="zeros")
    pos = PS((), (), init="zeros", dtype=jnp.int32)
    if cfg.family in (Family.DENSE, Family.VLM, Family.MOE):
        return {"k": kv(max_len), "v": kv(max_len), "pos": pos}
    if cfg.family == Family.SSM:
        di, n, K = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
        return {
            "conv": PS((L, batch, K - 1, di),
                       ("layers", "batch", "conv", "d_inner"), init="zeros"),
            "ssm": PS((L, batch, di, n),
                      ("layers", "batch", "d_inner", "state"),
                      init="zeros", dtype=jnp.float32),
            "pos": pos,
        }
    if cfg.family == Family.HYBRID:
        di, n, K = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
        nh = di // cfg.ssm.head_dim
        G = L // cfg.shared_attn_period
        return {
            "conv_x": PS((L, batch, K - 1, di),
                         ("layers", "batch", "conv", "d_inner"), init="zeros"),
            "conv_B": PS((L, batch, K - 1, n),
                         ("layers", "batch", "conv", "state"), init="zeros"),
            "conv_C": PS((L, batch, K - 1, n),
                         ("layers", "batch", "conv", "state"), init="zeros"),
            "ssm": PS((L, batch, nh, cfg.ssm.head_dim, n),
                      ("layers", "batch", "ssm_heads", "hd", "state"),
                      init="zeros", dtype=jnp.float32),
            "shared_k": PS((G, batch, max_len, KV, hd),
                           ("groups", "batch", "seq", "heads_kv", "hd_cache"),
                           init="zeros"),
            "shared_v": PS((G, batch, max_len, KV, hd),
                           ("groups", "batch", "seq", "heads_kv", "hd_cache"),
                           init="zeros"),
            "pos": pos,
        }
    if cfg.family in (Family.ENC_DEC, Family.AUDIO):
        return {
            "k": kv(max_len), "v": kv(max_len),
            "cross_k": PS((L, batch, enc_len, KV, hd),
                          ("layers", "batch", "seq", "heads_kv", "hd_cache"),
                          init="zeros"),
            "cross_v": PS((L, batch, enc_len, KV, hd),
                          ("layers", "batch", "seq", "heads_kv", "hd_cache"),
                          init="zeros"),
            "pos": pos,
        }
    raise ValueError(cfg.family)
