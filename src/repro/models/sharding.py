"""Logical-axis sharding: a single schema drives both parameter shapes and
their PartitionSpecs, so init, optimizer state, and pjit in_shardings can
never drift apart.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Logical parameter axes are mapped to mesh axes by rules that are
derived per architecture (divisibility permitting).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ArchConfig, Family

MeshAxes = Union[str, Tuple[str, ...], None]


@dataclass(frozen=True)
class ParamSchema:
    """One parameter: shape + logical axis names + init style."""

    shape: Tuple[int, ...]
    logical: Tuple[str, ...]
    init: str = "normal"        # normal | zeros | ones | small_normal
    dtype: Any = None           # defaults to the model param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


@dataclass
class ShardingRules:
    """Map from logical axis name to mesh axes (or None = replicate)."""

    rules: Dict[str, MeshAxes]

    def spec_for(self, logical: Sequence[str]) -> P:
        return P(*(self.rules.get(name) for name in logical))


def default_rules(
    cfg: ArchConfig,
    *,
    model_axis: str = "model",
    fsdp_axes: MeshAxes = "data",
    model_size: int = 16,
    fsdp_total: int = 16,
    batch_axes: MeshAxes = ("data",),
    seq_shard_cache: bool = False,
) -> ShardingRules:
    """Derive TP/FSDP rules for an architecture, respecting divisibility.

    * ``heads_q`` shards over the model axis when n_heads divides;
    * ``d_ff``/``d_inner``/``experts`` shard over the model axis;
    * ``d_model`` is the FSDP (ZeRO-3) axis (spanning pod x data when
      multi-pod);
    * vocab is padded to 256 so ``embed_vocab`` always shards;
    * decode caches: ``hd_cache`` shards head_dim over the model axis and
      optionally ``seq`` over data (B=1 long-context cells).
    """
    def fits(n: int, size: int) -> bool:
        return n % size == 0

    rules: Dict[str, MeshAxes] = {
        "layers": None,
        "groups": None,
        "scan": None,
        "d_model": fsdp_axes if fits(cfg.d_model, fsdp_total) else None,
        "embed_vocab": model_axis if fits(cfg.vocab_padded, model_size) else None,
        "heads_q": model_axis if fits(cfg.n_heads, model_size) else None,
        "heads_kv": model_axis if fits(cfg.n_kv_heads, model_size) else None,
        "hd": None,
        # Decode caches carry both a heads_kv and an hd_cache axis; a mesh
        # axis may appear once per spec, so hd_cache only shards when the
        # kv-head axis cannot (GQA with few kv heads).
        "hd_cache": model_axis
        if fits(cfg.hd, model_size) and not fits(cfg.n_kv_heads, model_size)
        else None,
        "d_ff": model_axis if cfg.d_ff and fits(cfg.d_ff, model_size) else None,
        "conv": None,
        "state": None,
        "dt": None,
        "scalar": None,
        "batch": batch_axes,
        "seq": "data" if seq_shard_cache else None,
    }
    if cfg.moe is not None:
        rules["experts"] = (
            model_axis if fits(cfg.moe.n_experts_padded, model_size) else None
        )
        # When experts shard over model, per-expert d_ff stays unsharded.
        if rules["experts"] is not None:
            rules["d_ff"] = None
    if cfg.ssm is not None:
        di = cfg.d_inner
        rules["d_inner"] = model_axis if fits(di, model_size) else None
        nh = di // cfg.ssm.head_dim
        rules["ssm_heads"] = model_axis if fits(nh, model_size) else None
    return ShardingRules(rules)


def schema_to_pspecs(schema, rules: ShardingRules):
    """Map a schema pytree to PartitionSpecs."""
    return jax.tree.map(
        lambda ps: rules.spec_for(ps.logical),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSchema),
    )


def init_from_schema(rng: jax.Array, schema, dtype) -> Any:
    """Numerically initialise a parameter pytree from its schema."""
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamSchema)
    )
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, ps in zip(keys, leaves):
        dt = ps.dtype or dtype
        if ps.init == "zeros":
            out.append(jnp.zeros(ps.shape, dt))
        elif ps.init == "ones":
            out.append(jnp.ones(ps.shape, dt))
        elif ps.init == "a_log":
            # mamba1: A = 1..n per channel; mamba2: A ~ U[1, 16] per head.
            n = ps.shape[-1]
            if len(ps.shape) >= 2 and n > 1:
                a = jnp.broadcast_to(
                    jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), ps.shape
                )
            else:
                a = jnp.log(
                    1.0 + 15.0 * jax.random.uniform(key, ps.shape)
                )
            out.append(a.astype(dt))
        elif ps.init == "dt_bias":
            # softplus(dt_bias) ~ U[1e-3, 1e-1] (mamba init)
            u = jax.random.uniform(
                key, ps.shape, minval=np.log(1e-3), maxval=np.log(1e-1)
            )
            dt_ = jnp.exp(u)
            out.append((dt_ + jnp.log(-jnp.expm1(-dt_))).astype(dt))
        else:
            fan_in = ps.shape[-2] if len(ps.shape) >= 2 else ps.shape[-1]
            scale = 0.02 if ps.init == "small_normal" else 1.0 / np.sqrt(fan_in)
            out.append(scale * jax.random.normal(key, ps.shape, dt))
    return jax.tree.unflatten(treedef, out)


def abstract_from_schema(schema, dtype) -> Any:
    """ShapeDtypeStruct pytree (for dry-run lowering: no allocation)."""
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, ps.dtype or dtype),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSchema),
    )
