"""Parameter schemas per architecture family.

A schema is a nested dict of ParamSchema leaves; shapes, logical sharding
axes, and init style are defined once and consumed by init, dry-run
ShapeDtypeStructs, and pjit in_shardings alike.
"""
from __future__ import annotations

from typing import Dict

from .config import ArchConfig, Family, MLPKind
from .sharding import ParamSchema as PS


def _attn_schema(cfg: ArchConfig, L: int | None, cross: bool = False) -> Dict:
    """Attention block; L=None -> unstacked (shared block)."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def shp(*s):
        return (L, *s) if L is not None else s

    def lg(*a):
        return ("layers", *a) if L is not None else a

    out = {
        "ln": PS(shp(d), lg("d_model"), init="ones"),
        "wq": PS(shp(d, H, hd), lg("d_model", "heads_q", "hd")),
        "wk": PS(shp(d, KV, hd), lg("d_model", "heads_kv", "hd")),
        "wv": PS(shp(d, KV, hd), lg("d_model", "heads_kv", "hd")),
        "wo": PS(shp(H, hd, d), lg("heads_q", "hd", "d_model")),
    }
    if cfg.qkv_bias:
        out["bq"] = PS(shp(H, hd), lg("heads_q", "hd"), init="zeros")
        out["bk"] = PS(shp(KV, hd), lg("heads_kv", "hd"), init="zeros")
        out["bv"] = PS(shp(KV, hd), lg("heads_kv", "hd"), init="zeros")
    return out


def _mlp_schema(cfg: ArchConfig, L: int | None) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff

    def shp(*s):
        return (L, *s) if L is not None else s

    def lg(*a):
        return ("layers", *a) if L is not None else a

    out = {"ln": PS(shp(d), lg("d_model"), init="ones")}
    if cfg.mlp == MLPKind.GATED_SILU:
        out["w_gate"] = PS(shp(d, ff), lg("d_model", "d_ff"))
        out["w_up"] = PS(shp(d, ff), lg("d_model", "d_ff"))
        out["w_down"] = PS(shp(ff, d), lg("d_ff", "d_model"))
    else:
        out["w_up"] = PS(shp(d, ff), lg("d_model", "d_ff"))
        out["w_down"] = PS(shp(ff, d), lg("d_ff", "d_model"))
        if cfg.qkv_bias:  # whisper-style biased MLP
            out["b_up"] = PS(shp(ff), lg("d_ff"), init="zeros")
            out["b_down"] = PS(shp(d), lg("d_model"), init="zeros")
    return out


def _moe_schema(cfg: ArchConfig, L: int) -> Dict:
    d, ff, Ep = cfg.d_model, cfg.d_ff, cfg.moe.n_experts_padded
    out = {
        "ln": PS((L, d), ("layers", "d_model"), init="ones"),
        "router": PS((L, d, Ep), ("layers", "d_model", "experts"),
                     init="small_normal"),
        "w_up": PS((L, Ep, d, ff), ("layers", "experts", "d_model", "d_ff")),
        "w_down": PS((L, Ep, ff, d), ("layers", "experts", "d_ff", "d_model")),
    }
    if cfg.mlp == MLPKind.GATED_SILU:
        out["w_gate"] = PS(
            (L, Ep, d, ff), ("layers", "experts", "d_model", "d_ff")
        )
    return out


def _mamba1_schema(cfg: ArchConfig, L: int) -> Dict:
    d, di, n, K = cfg.d_model, cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
    r = max(1, d // 16)
    return {
        "ln": PS((L, d), ("layers", "d_model"), init="ones"),
        "w_in": PS((L, d, 2 * di), ("layers", "d_model", "d_inner")),
        "conv_w": PS((L, K, di), ("layers", "conv", "d_inner"),
                     init="small_normal"),
        "conv_b": PS((L, di), ("layers", "d_inner"), init="zeros"),
        "w_xproj": PS((L, di, r + 2 * n), ("layers", "d_inner", "dt")),
        "w_dt": PS((L, r, di), ("layers", "dt", "d_inner")),
        "dt_bias": PS((L, di), ("layers", "d_inner"), init="dt_bias"),
        "A_log": PS((L, di, n), ("layers", "d_inner", "state"), init="a_log"),
        "D": PS((L, di), ("layers", "d_inner"), init="ones"),
        "w_out": PS((L, di, d), ("layers", "d_inner", "d_model")),
    }


def _mamba2_schema(cfg: ArchConfig, L: int) -> Dict:
    d, di, n, K = cfg.d_model, cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
    nh = di // cfg.ssm.head_dim
    return {
        "ln": PS((L, d), ("layers", "d_model"), init="ones"),
        "wz": PS((L, d, di), ("layers", "d_model", "d_inner")),
        "wx": PS((L, d, di), ("layers", "d_model", "d_inner")),
        "wB": PS((L, d, n), ("layers", "d_model", "state")),
        "wC": PS((L, d, n), ("layers", "d_model", "state")),
        "wdt": PS((L, d, nh), ("layers", "d_model", "ssm_heads")),
        "conv_x_w": PS((L, K, di), ("layers", "conv", "d_inner"),
                       init="small_normal"),
        "conv_x_b": PS((L, di), ("layers", "d_inner"), init="zeros"),
        "conv_B_w": PS((L, K, n), ("layers", "conv", "state"),
                       init="small_normal"),
        "conv_B_b": PS((L, n), ("layers", "state"), init="zeros"),
        "conv_C_w": PS((L, K, n), ("layers", "conv", "state"),
                       init="small_normal"),
        "conv_C_b": PS((L, n), ("layers", "state"), init="zeros"),
        "A_log": PS((L, nh), ("layers", "ssm_heads"), init="a_log"),
        "D": PS((L, nh), ("layers", "ssm_heads"), init="ones"),
        "dt_bias": PS((L, nh), ("layers", "ssm_heads"), init="dt_bias"),
        "out_norm": PS((L, di), ("layers", "d_inner"), init="ones"),
        "w_out": PS((L, di, d), ("layers", "d_inner", "d_model")),
    }


def build_schema(cfg: ArchConfig) -> Dict:
    """Full parameter schema for an architecture."""
    d, Vp, L = cfg.d_model, cfg.vocab_padded, cfg.n_layers
    schema: Dict = {
        "embed": PS((Vp, d), ("embed_vocab", "d_model"), init="small_normal"),
        "final_norm": PS((d,), ("d_model",), init="ones"),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = PS((d, Vp), ("d_model", "embed_vocab"))

    if cfg.family in (Family.DENSE, Family.VLM):
        schema["layers"] = {
            "attn": _attn_schema(cfg, L),
            "mlp": _mlp_schema(cfg, L),
        }
    elif cfg.family == Family.MOE:
        schema["layers"] = {
            "attn": _attn_schema(cfg, L),
            "moe": _moe_schema(cfg, L),
        }
    elif cfg.family == Family.SSM:
        schema["layers"] = _mamba1_schema(cfg, L)
    elif cfg.family == Family.HYBRID:
        schema["layers"] = _mamba2_schema(cfg, L)
        schema["shared"] = {
            "attn": _attn_schema(cfg, None),
            "mlp": _mlp_schema(cfg, None),
        }
    elif cfg.family in (Family.ENC_DEC, Family.AUDIO):
        schema["enc_layers"] = {
            "attn": _attn_schema(cfg, L),
            "mlp": _mlp_schema(cfg, L),
        }
        schema["enc_final_norm"] = PS((d,), ("d_model",), init="ones")
        schema["layers"] = {
            "attn": _attn_schema(cfg, L),
            "cross": _attn_schema(cfg, L),
            "mlp": _mlp_schema(cfg, L),
        }
    else:
        raise ValueError(cfg.family)
    return schema
