"""State-space blocks: mamba1 selective scan and mamba2 (SSD) chunked scan.

Both have a full-sequence path (training / prefill) and an O(1) recurrent
decode step.  The full-sequence mamba2 path uses the chunked SSD algorithm
(intra-chunk quadratic + inter-chunk state passing), which is also the
blueprint for the Pallas kernel in ``repro.kernels.ssd_scan``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .ops import ShardCtx, rms_norm


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C); b: (C,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return out + b[None, None, :]


def conv_step(
    x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """One causal-conv decode step.  x_t: (B, C); conv_state: (B, K-1, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b[None, :]
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# mamba1 (falcon-mamba)
# ---------------------------------------------------------------------------


def mamba1_scan(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array, Cc: jax.Array,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Selective scan.  x, dt: (B,S,di); A: (di,n); Bc, Cc: (B,S,n).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    Associative scan over S (log-depth).  Returns (y (B,S,di), h_S).
    """
    dA = jnp.exp(dt[..., None] * A[None, None])                  # (B,S,di,n)
    dBx = (dt * x)[..., None] * Bc[:, :, None, :]                # (B,S,di,n)
    if h0 is not None:
        # fold carry-in into the first step
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    aA, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cc)
    return y, h[:, -1]


def mamba1_block(
    p: Dict, x: jax.Array, cfg: ArchConfig, ctx: ShardCtx,
    cache: Optional[Dict] = None, return_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Full mamba1 block.  x: (B, S, d).  With ``cache`` (decode), S == 1.
    ``return_state`` (prefill): emit {conv, ssm} states for later decode."""
    ssm = cfg.ssm
    di, n = cfg.d_inner, ssm.d_state
    dt_rank = max(1, cfg.d_model // 16)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xz = h @ p["w_in"]                             # (B,S,2*di)
    xi, z = xz[..., :di], xz[..., di:]
    xi = ctx.act(xi, ctx.dp, None, ctx.tp)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))   # (di,n)

    if cache is None:
        K = ssm.d_conv
        xc = jax.nn.silu(causal_conv(xi, p["conv_w"], p["conv_b"]))
        xdb = xc @ p["w_xproj"]                    # (B,S,r+2n)
        dt = jax.nn.softplus(xdb[..., :dt_rank] @ p["w_dt"] + p["dt_bias"])
        Bc = xdb[..., dt_rank : dt_rank + n].astype(jnp.float32)
        Cc = xdb[..., dt_rank + n :].astype(jnp.float32)
        y, h_fin = mamba1_scan(
            xc.astype(jnp.float32), dt.astype(jnp.float32), A, Bc, Cc
        )
        y = y.astype(x.dtype) + xc * p["D"][None, None, :]
        out = (y * jax.nn.silu(z)) @ p["w_out"]
        state = None
        if return_state:
            state = {"conv": xi[:, -(K - 1):, :], "ssm": h_fin}
        return x + out, state

    # --- decode step ------------------------------------------------------
    x_t = xi[:, 0]                                  # (B, di)
    xc, conv_state = conv_step(x_t, cache["conv"], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    xdb = xc @ p["w_xproj"]
    dt = jax.nn.softplus(xdb[..., :dt_rank] @ p["w_dt"] + p["dt_bias"])
    Bc = xdb[..., dt_rank : dt_rank + n].astype(jnp.float32)
    Cc = xdb[..., dt_rank + n :].astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None])      # (B,di,n)
    hs = cache["ssm"] * dA + (dt * xc).astype(jnp.float32)[..., None] \
        * Bc[:, None, :]
    y = jnp.einsum("bdn,bn->bd", hs, Cc).astype(x.dtype)
    y = y + xc * p["D"][None, :]
    out = (y * jax.nn.silu(z[:, 0])) @ p["w_out"]
    return x + out[:, None, :], {"conv": conv_state, "ssm": hs}


# ---------------------------------------------------------------------------
# mamba2 / SSD (zamba2 backbone)
# ---------------------------------------------------------------------------


def segsum(dtA: jax.Array) -> jax.Array:
    """Lower-triangular cumulative decay: out[..., i, j] = sum_{j<k<=i} dtA_k
    for j <= i, -inf otherwise.  dtA: (..., Q)."""
    Q = dtA.shape[-1]
    cs = jnp.cumsum(dtA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array, Cc: jax.Array,
    chunk: int, h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD, chunked.  x: (B,S,nh,hp); dt: (B,S,nh); A: (nh,) (<0);
    Bc, Cc: (B,S,n) (shared across heads).  Returns (y, h_final (B,nh,hp,n)).
    """
    B_, S, nh, hp = x.shape
    n = Bc.shape[-1]
    S0 = S
    if S % chunk:
        # pad to a chunk multiple: padded steps have dt = 0, so exp(dt*A) = 1
        # and dt*B*x = 0 — the state passes through unchanged.
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk

    # reshape into chunks
    xc = x.reshape(B_, nc, chunk, nh, hp)
    dtc = dt.reshape(B_, nc, chunk, nh)
    Bcc = Bc.reshape(B_, nc, chunk, n)
    Ccc = Cc.reshape(B_, nc, chunk, n)
    dtA = dtc * A[None, None, None, :]                     # (B,nc,Q,nh)

    # intra-chunk (quadratic within chunk)
    L = jnp.exp(segsum(dtA.swapaxes(-1, -2)))              # (B,nc,nh,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Ccc, Bcc)       # (B,nc,Q,Q)
    y_intra = _ssd_intra(L, scores, dtc, xc)

    # chunk state: S_c = sum_k exp(sum_{j>k} dtA_j) dt_k B_k x_k
    dtA_cum = jnp.cumsum(dtA, axis=2)                      # (B,nc,Q,nh)
    decay_to_end = jnp.exp(dtA_cum[:, :, -1:, :] - dtA_cum)  # (B,nc,Q,nh)
    states = jnp.einsum(
        "bcqh,bcqh,bcqn,bcqhp->bchpn", decay_to_end, dtc, Bcc, xc
    )                                                      # (B,nc,nh,hp,n)

    # inter-chunk recurrence (sequential over nc, nc is small)
    chunk_decay = jnp.exp(dtA_cum[:, :, -1, :])            # (B,nc,nh)

    def step(h, inp):
        s_c, dec = inp                                     # (B,nh,hp,n),(B,nh)
        h_new = h * dec[..., None, None] + s_c
        return h_new, h                                    # emit state BEFORE chunk

    h_init = jnp.zeros((B_, nh, hp, n), x.dtype) if h0 is None else h0
    h_fin, h_prevs = jax.lax.scan(
        step, h_init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    h_prevs = h_prevs.swapaxes(0, 1)                       # (B,nc,nh,hp,n)

    # inter-chunk contribution: y_inter[q] = exp(dtA_cum[q]) C_q . h_prev
    in_decay = jnp.exp(dtA_cum)                            # (B,nc,Q,nh)
    y_inter = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", Ccc, h_prevs, in_decay
    )
    y = (y_intra + y_inter).reshape(B_, S, nh, hp)[:, :S0]
    return y, h_fin


def _ssd_intra(L, scores, dtc, xc):
    """y_intra = sum_k L[h,q,k] * scores[q,k] * dt[k,h] * x[k,h,p]."""
    w = L * scores[:, :, None, :, :]                       # (B,nc,nh,Q,Q)
    wdt = w * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # * dt_k
    return jnp.einsum("bchqk,bckhp->bcqhp", wdt, xc)


def mamba2_block(
    p: Dict, x: jax.Array, cfg: ArchConfig, ctx: ShardCtx,
    cache: Optional[Dict] = None, return_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Mamba2 block (zamba2 backbone layer).  x: (B,S,d)."""
    ssm = cfg.ssm
    di, n, hp = cfg.d_inner, ssm.d_state, ssm.head_dim
    nh = di // hp
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    z = h @ p["wz"]
    xi = h @ p["wx"]
    Bc = h @ p["wB"]
    Cc = h @ p["wC"]
    dt = jax.nn.softplus(h @ p["wdt"] + p["dt_bias"])      # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (nh,)

    if cache is None:
        K = ssm.d_conv
        xc = jax.nn.silu(causal_conv(xi, p["conv_x_w"], p["conv_x_b"]))
        Bcv = jax.nn.silu(causal_conv(Bc, p["conv_B_w"], p["conv_B_b"]))
        Ccv = jax.nn.silu(causal_conv(Cc, p["conv_C_w"], p["conv_C_b"]))
        xh = xc.reshape(*xc.shape[:2], nh, hp)
        if ctx.ssm_impl == "pallas":
            from repro.kernels.ops import ssd_scan

            y, h_fin = ssd_scan(
                xh.astype(jnp.float32), dt.astype(jnp.float32), A,
                Bcv.astype(jnp.float32), Ccv.astype(jnp.float32),
                chunk=ssm.chunk,
            )
        else:
            y, h_fin = ssd_chunked(
                xh.astype(jnp.float32), dt.astype(jnp.float32), A,
                Bcv.astype(jnp.float32), Ccv.astype(jnp.float32), ssm.chunk,
            )
        y = y.astype(x.dtype) + xh * p["D"][None, None, :, None]
        y = y.reshape(*xc.shape[:2], di)
        y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
        state = None
        if return_state:
            state = {
                "conv_x": xi[:, -(K - 1):, :],
                "conv_B": Bc[:, -(K - 1):, :],
                "conv_C": Cc[:, -(K - 1):, :],
                "ssm": h_fin,
            }
        return x + y @ p["w_out"], state

    # --- decode -------------------------------------------------------------
    xc, conv_x = conv_step(xi[:, 0], cache["conv_x"], p["conv_x_w"], p["conv_x_b"])
    Bcv, conv_B = conv_step(Bc[:, 0], cache["conv_B"], p["conv_B_w"], p["conv_B_b"])
    Ccv, conv_C = conv_step(Cc[:, 0], cache["conv_C"], p["conv_C_w"], p["conv_C_b"])
    xc, Bcv, Ccv = jax.nn.silu(xc), jax.nn.silu(Bcv), jax.nn.silu(Ccv)
    xh = xc.reshape(-1, nh, hp).astype(jnp.float32)
    dt0 = dt[:, 0].astype(jnp.float32)                      # (B,nh)
    dA = jnp.exp(dt0 * A[None])                             # (B,nh)
    hs = cache["ssm"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt0, xh, Bcv.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", hs, Ccv.astype(jnp.float32))
    y = y.astype(x.dtype) + xh.astype(x.dtype) * p["D"][None, :, None]
    y = y.reshape(-1, di)
    y = rms_norm(y * jax.nn.silu(z[:, 0]), p["out_norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    return x + out[:, None, :], {
        "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "ssm": hs,
    }
