"""Pallas TPU flash attention (GQA, causal) with online softmax.

TPU adaptation notes (vs the CUDA flash-attention blueprint):
  * the grid's innermost dimension iterates SEQUENTIALLY on TPU, so the
    running (m, l, acc) online-softmax statistics live in VMEM scratch and
    persist across the key-block dimension — no atomics / shared-memory
    reductions as on GPU;
  * BlockSpec tiling keeps one (block_q, hd) query tile and one
    (block_k, hd) key/value tile resident in VMEM; block sizes default to
    multiples of 128 to align the MXU contraction dims;
  * GQA is expressed through the k/v index_map (query head h reads kv head
    h // group) — no repeat/materialisation of kv heads in HBM;
  * causal masking skips fully-masked key blocks via pl.when on block
    indices (structural, not data-dependent).

VMEM budget per program at defaults (block_q = block_k = 512, hd = 128,
bf16 in / f32 scratch): q 128KiB + k/v 256KiB + acc 256KiB + o 128KiB
< 1MiB — comfortably inside the ~16MiB/core VMEM of a v5e.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,      # inputs
    o_ref,                    # output
    m_scr, l_scr, acc_scr,    # VMEM scratch (persist across the k grid dim)
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # A key block is live unless it is entirely in the causal future of the
    # whole query block: first q position >= last k position required.
    live = (iq + 1) * block_q - 1 >= jk * block_k if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bk)
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = jk * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_scr[...]                           # (bq,)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_cur

    @pl.when(jk == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_bhsd(
    q: jax.Array,     # (B, H, Sq, hd)
    k: jax.Array,     # (B, KV, Sk, hd)
    v: jax.Array,     # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention over head-major layout.  Requires Sq == Sk when
    causal (self-attention train/prefill — the kernel's target use)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    if causal:
        assert Sq == Sk, "causal flash kernel assumes self-attention"

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel,
        scale=1.0 / (hd ** 0.5),
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b, h, i, j, g=group: (b, h // g, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b, h, i, j, g=group: (b, h // g, j, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m: running max
            pltpu.VMEM((block_q,), jnp.float32),      # l: running denom
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc: running numerator
        ],
        interpret=interpret,
    )(q, k, v)
