"""Public jit'd wrappers for the Pallas kernels.

These accept the model-native layouts ((B, S, H, hd) attention /
(B, S, nh, hp) SSD), transpose to the kernels' head-major layouts, and
select ``interpret=True`` automatically off-TPU so the same call sites run
on CPU (tests) and TPU (production) unchanged.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .ssd_scan import ssd_scan_bhsp


def _default_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _fit_block(size: int, target: int) -> int:
    """Largest divisor of ``size`` that is <= target (tests use odd sizes)."""
    b = min(target, size)
    while size % b:
        b -= 1
    return b


def flash_attention(
    q: jax.Array,      # (B, Sq, H, hd)
    k: jax.Array,      # (B, Sk, KV, hd)
    v: jax.Array,      # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash attention in the model layout; returns (B, Sq, H, hd)."""
    qt = q.swapaxes(1, 2)   # (B, H, Sq, hd)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    bq = _fit_block(q.shape[1], block_q)
    bk = _fit_block(k.shape[1], block_k)
    if causal and q.shape[1] == k.shape[1]:
        bq = bk = min(bq, bk)
    out = flash_attention_bhsd(
        qt, kt, vt,
        causal=causal, block_q=bq, block_k=bk,
        interpret=_default_interpret(interpret),
    )
    return out.swapaxes(1, 2)


def ssd_scan(
    x: jax.Array,      # (B, S, nh, hp)
    dt: jax.Array,     # (B, S, nh)
    A: jax.Array,      # (nh,)
    Bc: jax.Array,     # (B, S, n)
    Cc: jax.Array,     # (B, S, n)
    *,
    chunk: int = 256,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """SSD scan in the model layout.  Pads S to a chunk multiple with
    dt = 0 steps (exact state no-ops).  Returns (y (B, S, nh, hp) f32,
    h_final (B, nh, hp, n) f32)."""
    B, S, nh, hp = x.shape
    S0 = S
    chunk = min(chunk, S) if S % chunk == 0 or S < chunk else chunk
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    y, hfin = ssd_scan_bhsp(
        x.transpose(0, 2, 1, 3),      # (B, nh, S, hp)
        dt.transpose(0, 2, 1),        # (B, nh, S)
        A, Bc, Cc,
        chunk=chunk, interpret=_default_interpret(interpret),
    )
    return y.transpose(0, 2, 1, 3)[:, :S0], hfin
