"""Pallas TPU kernel for the mamba2 SSD chunked scan.

The SSD decomposition (Dao & Gu, 2024) splits the selective-state-space
recurrence into an intra-chunk quadratic part (an attention-like (Q x Q)
contraction that maps onto the MXU) and an inter-chunk state recurrence.
On TPU the natural mapping is:

  * grid (B, nh, n_chunks) with the CHUNK dimension innermost — TPU grids
    iterate the last dimension sequentially, so the running state h
    (hp x n) lives in VMEM scratch and flows chunk-to-chunk without any
    HBM round-trip (the GPU formulation materialises per-chunk states to
    HBM and runs a separate state-passing kernel; on TPU the sequential
    grid makes that second kernel and its HBM traffic unnecessary);
  * per-chunk tiles: x (Q, hp), dt (Q,), B/C (Q, n) are staged into VMEM
    by BlockSpecs; Q defaults to 256 and hp, n are 64-128 for the
    assigned archs, so all tiles are MXU-aligned (multiples of (8, 128)
    after padding) and the working set is < 1 MiB;
  * the decay matrix L = exp(segsum(dt*A)) is built in-register from a
    cumulative sum — no HBM materialisation of the (Q, Q) mask.

The final state is emitted so prefill can hand the cache to decode.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256
NEG_INF = -1e30


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,    # inputs
    y_ref, hfin_ref,                        # outputs
    h_scr,                                  # (hp, n) carried state
    *,
    chunk: int,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, hp)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    A = a_ref[0].astype(jnp.float32)             # scalar (this head)
    Bm = b_ref[0].astype(jnp.float32)            # (Q, n)
    Cm = c_ref[0].astype(jnp.float32)            # (Q, n)

    dtA = dt * A                                  # (Q,)
    cum = jnp.cumsum(dtA)                         # (Q,)

    # intra-chunk: y[q] += sum_{k<=q} exp(cum[q]-cum[k]) (C_q.B_k) dt_k x_k
    seg = cum[:, None] - cum[None, :]             # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(qi >= ki, jnp.exp(seg), 0.0)    # lower-tri decay
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (Q, Q) = C_q . B_k
    w = L * scores * dt[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (Q, hp)

    # inter-chunk: y[q] += exp(cum[q]) C_q . h_prev      (h_prev: (hp, n))
    h_prev = h_scr[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # state update: h = exp(cum[-1]) h_prev
    #                  + sum_k exp(cum[-1]-cum[k]) dt_k x_k B_k^T
    decay_to_end = jnp.exp(cum[-1] - cum) * dt    # (Q,)
    upd = jax.lax.dot_general(
        x * decay_to_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (hp, n)
    h_scr[...] = jnp.exp(cum[-1]) * h_prev + upd

    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        hfin_ref[0, 0, :, :] = h_scr[...].astype(hfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bhsp(
    x: jax.Array,      # (B, nh, S, hp)
    dt: jax.Array,     # (B, nh, S)
    A: jax.Array,      # (nh,)  negative
    Bc: jax.Array,     # (B, S, n)   shared across heads
    Cc: jax.Array,     # (B, S, n)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Head-major SSD scan.  S must be a multiple of ``chunk`` (callers pad
    with dt = 0 steps, which are exact no-ops on the state).

    Returns (y (B, nh, S, hp) f32, h_final (B, nh, hp, n) f32).
    """
    B, nh, S, hp = x.shape
    n = Bc.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    grid = (B, nh, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, hfin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, n), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hp, n), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nh, S, hp), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hp, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hp, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bc, Cc)
    return y, hfin
