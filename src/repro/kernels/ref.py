"""Pure-jnp oracles for the Pallas kernels.

These are deliberately the *naive* formulations (materialised scores /
sequential state recurrence), independent from both the kernels and the
XLA-portable chunked paths in ``repro.models`` — so a kernel bug and a
model-path bug cannot cancel out in tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    """Naive softmax attention with GQA.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd), H a multiple of KV.
    Returns (B, Sq, H, hd) in q.dtype; math in f32.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    kf = jnp.repeat(k, G, axis=2).astype(jnp.float32)   # (B, Sk, H, hd)
    vf = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf)
    s = s / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.arange(Sq)[:, None] + (Sk - Sq) >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    return out.astype(q.dtype)


def ssd_ref(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bc: jax.Array,
    Cc: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential (step-by-step) mamba2/SSD recurrence — the slow oracle.

    x: (B, S, nh, hp); dt: (B, S, nh); A: (nh,) (negative);
    Bc, Cc: (B, S, n) shared across heads.

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    Returns (y (B, S, nh, hp), h_final (B, nh, hp, n)); math in f32.
    """
    B_, S, nh, hp = x.shape
    n = Bc.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp          # (B,nh,hp), (B,nh), (B,n), (B,n)
        decay = jnp.exp(dt_t * Af[None])   # (B, nh)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, B_t)
        h = h * decay[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y_t

    h0 = jnp.zeros((B_, nh, hp, n), jnp.float32)
    h_fin, ys = jax.lax.scan(
        step, h0,
        (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
         Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1), h_fin
