"""Pallas TPU kernels for the performance-critical compute layers.

  flash_attention — GQA causal attention, online softmax, VMEM tiling
  ssd_scan        — mamba2 SSD chunked scan with VMEM-resident state

``ops`` holds the jit'd model-layout wrappers; ``ref`` the pure-jnp
oracles the tests sweep against (interpret=True on CPU).
"""
from .ops import flash_attention, ssd_scan  # noqa: F401
