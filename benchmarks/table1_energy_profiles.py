"""Table 1 reproduction: per-(service, flavour) energy profiles recovered
from the synthetic monitoring window through Eq. 1.

The monitoring stand-in is built so its per-(s,f) mean equals Table 1; the
benchmark verifies the Energy Estimator recovers each value bit-for-bit and
times the estimation."""
import time

from repro.configs import boutique
from repro.core.energy import EnergyEstimator


def run(report=print):
    app, infra, mon = boutique.scenario(1)
    est = EnergyEstimator()
    t0 = time.perf_counter()
    profiles = est.computation_profiles(mon)
    dt_us = (time.perf_counter() - t0) * 1e6

    rows = []
    worst = 0.0
    for sid, flavs in boutique.TABLE1.items():
        for fname, expected in flavs:
            got = profiles[(sid, fname)]
            err = abs(got - expected) / expected
            worst = max(worst, err)
            rows.append((sid, fname, expected, got, err))

    report(f"# Table 1: energy profiles (Eq. 1) — {len(rows)} (s,f) pairs, "
           f"estimation {dt_us:.0f}us, worst rel err {worst:.2e}")
    report(f"{'service':<16}{'flavour':<9}{'Table1 kWh':>11}{'Eq.1 kWh':>11}")
    for sid, fname, exp, got, _ in rows:
        report(f"{sid:<16}{fname:<9}{exp:>11.1f}{got:>11.1f}")
    assert worst < 1e-9, f"Table 1 not recovered exactly (err {worst})"
    return {"rows": len(rows), "us_per_call": dt_us, "worst_rel_err": worst}


if __name__ == "__main__":
    run()
