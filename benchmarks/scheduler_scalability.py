"""Scheduler scalability: legacy object-walking vs array-native core.

Sweeps (S services, N nodes) and times one full `plan()` call (greedy +
local search) for the retained ``ReferenceScheduler`` and the vectorized
``GreenScheduler`` on the same synthetic problem and the same config.
Writes ``BENCH_scheduler.json`` so the perf trajectory is tracked from
this PR onward; asserts the vectorized plan's objective never exceeds the
legacy plan's and that the speedup at (S=200, N=100) is at least 10x.

The legacy path is O(S^2*F*N*(S+L)) per greedy pass, so the sweep keeps
``local_search_rounds`` small and caps the legacy side at (200, 100);
larger vectorized-only points show the array-native scaling headroom.
"""
import json
import random
import time

from repro.core.scheduler import (
    GreenScheduler,
    ReferenceScheduler,
    SchedulerConfig,
    reference_objective,
)
from repro.core.types import (
    Affinity,
    Application,
    AvoidNode,
    Flavour,
    FlavourRequirements,
    Infrastructure,
    Node,
    NodeCapabilities,
    Service,
)

OUT_JSON = "BENCH_scheduler.json"
REQUIRED_SPEEDUP = 10.0          # acceptance floor at (200, 100)


def synth(n_services: int, n_nodes: int, seed: int = 0, flavours: int = 2):
    """A dense-ish placement problem: F flavours per service, ring links,
    AvoidNode/Affinity soft constraints."""
    rnd = random.Random(seed)
    services = tuple(
        Service(f"s{i}", flavours=tuple(
            Flavour(f"f{k}", requirements=FlavourRequirements(
                cpu=rnd.choice([0.5, 1.0, 2.0]),
                ram_gb=rnd.choice([1.0, 2.0, 4.0])))
            for k in range(flavours)))
        for i in range(n_services)
    )
    nodes = tuple(
        Node(f"n{j}", carbon=rnd.uniform(10.0, 600.0),
             cost_per_cpu_hour=rnd.uniform(0.0, 2.0),
             capabilities=NodeCapabilities(
                 cpu=rnd.choice([8.0, 16.0]), ram_gb=64.0))
        for j in range(n_nodes)
    )
    comp = {
        (f"s{i}", f"f{k}"): rnd.uniform(1.0, 100.0)
        for i in range(n_services) for k in range(flavours)
    }
    comm = {
        (f"s{i}", "f0", f"s{(i + 1) % n_services}"): rnd.uniform(0.1, 20.0)
        for i in range(n_services)
    }
    cs = []
    for i in range(0, n_services, 3):
        cs.append(AvoidNode(service=f"s{i}", flavour="f0",
                            node=f"n{rnd.randrange(n_nodes)}",
                            weight=rnd.uniform(0.2, 1.0)))
    for i in range(0, n_services, 5):
        cs.append(Affinity(service=f"s{i}",
                           other=f"s{(i + 1) % n_services}",
                           weight=rnd.uniform(0.2, 1.0)))
    return (Application("synth", services), Infrastructure("synth", nodes),
            comp, comm, cs)


def _objective(plan, app, infra, comp, comm, cs, cfg):
    assign = {p.service: (p.flavour, p.node) for p in plan.placements}
    return reference_objective(app, infra, comp, comm, cs, cfg, assign)


def run(report=print, sweep=((50, 25), (100, 50), (200, 100)),
        vec_only_sweep=((500, 200), (1000, 400)), rounds: int = 2,
        out_json: str = OUT_JSON):
    cfg = SchedulerConfig.green()
    cfg.local_search_rounds = rounds
    rows = []
    report("# Scheduler wall time: legacy (ReferenceScheduler) vs "
           "array-native (GreenScheduler)")
    report(f"{'S':>5} {'N':>5} {'t_ref_s':>9} {'t_vec_s':>9} "
           f"{'speedup':>8} {'J_ref':>12} {'J_vec':>12}")
    for S, N in sweep:
        app, infra, comp, comm, cs = synth(S, N)
        t0 = time.perf_counter()
        ref = ReferenceScheduler(cfg).plan(app, infra, comp, comm, cs)
        t_ref = time.perf_counter() - t0
        t0 = time.perf_counter()
        vec = GreenScheduler(cfg).plan(app, infra, comp, comm, cs)
        t_vec = time.perf_counter() - t0
        j_ref = _objective(ref, app, infra, comp, comm, cs, cfg)
        j_vec = _objective(vec, app, infra, comp, comm, cs, cfg)
        assert vec.feasible == ref.feasible
        assert j_vec <= j_ref + 1e-9 * max(1.0, abs(j_ref)), \
            (S, N, j_ref, j_vec)
        speedup = t_ref / max(t_vec, 1e-9)
        rows.append({"S": S, "N": N, "t_ref_s": t_ref, "t_vec_s": t_vec,
                     "speedup": speedup, "J_ref": j_ref, "J_vec": j_vec})
        report(f"{S:>5} {N:>5} {t_ref:>9.3f} {t_vec:>9.3f} "
               f"{speedup:>7.1f}x {j_ref:>12.3f} {j_vec:>12.3f}")

    vec_rows = []
    report("\n# Array-native only (legacy intractable at this scale)")
    report(f"{'S':>5} {'N':>5} {'t_vec_s':>9}")
    for S, N in vec_only_sweep:
        app, infra, comp, comm, cs = synth(S, N)
        t0 = time.perf_counter()
        plan = GreenScheduler(cfg).plan(app, infra, comp, comm, cs)
        t_vec = time.perf_counter() - t0
        assert plan.feasible
        vec_rows.append({"S": S, "N": N, "t_vec_s": t_vec})
        report(f"{S:>5} {N:>5} {t_vec:>9.3f}")

    top = max(rows, key=lambda r: (r["S"], r["N"]))
    report(f"\n# speedup at S={top['S']}, N={top['N']}: "
           f"{top['speedup']:.1f}x")
    # the 10x acceptance floor is defined at (S=200, N=100); only enforce
    # it when the sweep actually contains that point (quick sweeps don't)
    gate = [r for r in rows if (r["S"], r["N"]) == (200, 100)]
    if gate:
        report(f"# acceptance: {gate[0]['speedup']:.1f}x at (200, 100) "
               f"(floor {REQUIRED_SPEEDUP:.0f}x)")
        assert gate[0]["speedup"] >= REQUIRED_SPEEDUP, gate[0]

    out = {"config": {"local_search_rounds": rounds, "profile": "green"},
           "old_vs_vectorized": rows, "vectorized_only": vec_rows}
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2)
        report(f"# wrote {out_json}")
    return out


if __name__ == "__main__":
    run()
