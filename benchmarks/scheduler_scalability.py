"""Scheduler scalability: legacy object-walking vs array-native core.

Sweeps (S services, N nodes) and times one full ``plan(problem)`` call
(greedy + local search) for the retained ``ReferenceScheduler`` and the
unified ``GreenScheduler`` on the same synthetic problem and the same
config.  GreenScheduler timings EXCLUDE the one-time XLA compile (one
warmup call per shape): the adaptive loop replans the same shapes every
tick, so steady-state cost is what the trajectory tracks.

Beyond the shared sweep, a sparse-backend frontier section plans an
S=2000, N=200 problem through ``SparseCommLowering`` — a scale where the
dense ``[S, F, S]`` communication tensors and the O(S^2*F*N) move-grid
einsum are reported infeasible to materialize by the auto-selection
policy (``SPARSE_AUTO_THRESHOLD``), and records what the dense backend
WOULD have allocated.

A compile-cache section (run FIRST, against a cold planner cache) plans a
mixed-shape sweep through the shape-bucketed planner (``BucketSpec`` grid
with the acceptance point (200, 100) as a boundary): every shape rounds
up to one bucket, so >= 8 shapes must cost >= 4x fewer XLA compiles than
shapes, and bucketed steady-state plan time at the boundary must stay
within 1.25x of the exact-shape time.

Writes ``BENCH_scheduler.json`` so the perf trajectory is tracked
PR-over-PR; asserts the array-native plan's objective never exceeds the
legacy plan's, that dense and sparse backends agree at a shared point,
and that the speedup at (S=200, N=100) is at least 10x.

CI runs ``--smoke --check BENCH_scheduler.json``: a small sweep whose
measured speedup must stay within --tolerance (default 20%) of the
committed baseline's at the same point, plus the compile-cache hit-rate
gate over the mixed-shape smoke sweep.  Set
``JAX_COMPILATION_CACHE_DIR`` to persist compiled programs across runs
(CI caches it so cold compiles are paid once per toolchain bump).

  PYTHONPATH=src python -m benchmarks.scheduler_scalability [--smoke]
      [--check BENCH_scheduler.json] [--tolerance 0.2]
"""
import argparse
import json
import random
import sys
import time

from benchmarks.jax_cache import enable_persistent_cache

from repro.core.lowering import SPARSE_AUTO_THRESHOLD, lower
from repro.core.problem import BucketSpec, PlacementProblem
from repro.core.scheduler import (
    GreenScheduler,
    ReferenceScheduler,
    SchedulerConfig,
    reference_objective,
)
from repro.obs import metrics_scope
from repro.core.types import (
    Affinity,
    Application,
    AvoidNode,
    Flavour,
    FlavourRequirements,
    Infrastructure,
    Node,
    NodeCapabilities,
    Service,
)

OUT_JSON = "BENCH_scheduler.json"
REQUIRED_SPEEDUP = 10.0          # acceptance floor at (200, 100)
# Absolute speedup a healthy host shows at the smoke point, regardless of
# hardware (measured ~1000-2000x on dev machines): the relative >20%
# check below tracks PR-over-PR drift on comparable hosts, but a pure
# ratio of interpreter time to XLA time does not transfer across CPU
# generations — a host that still clears this floor is not failed on the
# relative check alone.
SMOKE_SPEEDUP_FLOOR = 200.0
FLAVOURS = 2

# Bucket boundaries for the compile-cache sweep: an explicit grid tuned
# to the sweep envelope (the acceptance point (200, 100) is a boundary,
# so bucketed planning there pays no padding overhead).
BUCKET_GRID = BucketSpec.grid(
    s=(25, 50, 100, 200, 400, 800, 1600),
    f=(2, 4),
    n=(25, 50, 100, 200, 400),
    b=(1, 2, 4, 8, 16),
)
# Mixed shapes that all round up to the (200, 100) bucket (full mode) /
# the (100, 50) bucket (smoke): >= 4x fewer XLA compiles than shapes.
CACHE_SWEEP = ((110, 60), (120, 70), (130, 80), (140, 90),
               (150, 100), (160, 60), (180, 80), (200, 100))
CACHE_SWEEP_SMOKE = ((60, 30), (70, 35), (80, 40), (100, 50))
# Bucketed steady-state time at the acceptance point must stay within
# this factor of the exact-shape time (the point IS a bucket boundary).
BUCKET_OVERHEAD_CEILING = 1.25


def synth(n_services: int, n_nodes: int, seed: int = 0,
          flavours: int = FLAVOURS):
    """A dense-ish placement problem: F flavours per service, ring links,
    AvoidNode/Affinity soft constraints."""
    rnd = random.Random(seed)
    services = tuple(
        Service(f"s{i}", flavours=tuple(
            Flavour(f"f{k}", requirements=FlavourRequirements(
                cpu=rnd.choice([0.5, 1.0, 2.0]),
                ram_gb=rnd.choice([1.0, 2.0, 4.0])))
            for k in range(flavours)))
        for i in range(n_services)
    )
    nodes = tuple(
        Node(f"n{j}", carbon=rnd.uniform(10.0, 600.0),
             cost_per_cpu_hour=rnd.uniform(0.0, 2.0),
             capabilities=NodeCapabilities(
                 cpu=rnd.choice([8.0, 16.0]), ram_gb=64.0))
        for j in range(n_nodes)
    )
    comp = {
        (f"s{i}", f"f{k}"): rnd.uniform(1.0, 100.0)
        for i in range(n_services) for k in range(flavours)
    }
    comm = {
        (f"s{i}", "f0", f"s{(i + 1) % n_services}"): rnd.uniform(0.1, 20.0)
        for i in range(n_services)
    }
    cs = []
    for i in range(0, n_services, 3):
        cs.append(AvoidNode(service=f"s{i}", flavour="f0",
                            node=f"n{rnd.randrange(n_nodes)}",
                            weight=rnd.uniform(0.2, 1.0)))
    for i in range(0, n_services, 5):
        cs.append(Affinity(service=f"s{i}",
                           other=f"s{(i + 1) % n_services}",
                           weight=rnd.uniform(0.2, 1.0)))
    return (Application("synth", services), Infrastructure("synth", nodes),
            comp, comm, cs)


def _objective(plan, app, infra, comp, comm, cs, cfg):
    assign = {p.service: (p.flavour, p.node) for p in plan.placements}
    return reference_objective(app, infra, comp, comm, cs, cfg, assign)


def _timed_plan(cfg, problem, repeats: int = 1):
    """Steady-state plan wall time (best of ``repeats``): one warmup call
    compiles the shape first."""
    sched = GreenScheduler(cfg)
    sched.plan(problem)
    best, result = None, None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        result = sched.plan(problem)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best, result.plan


def compile_cache_sweep(report, shapes, rounds: int, repeats: int,
                        overhead_point=None):
    """Plan a mixed-shape sweep through the shape-bucketed planner cache.

    Every shape in ``shapes`` rounds up to ONE bucket of
    :data:`BUCKET_GRID`, so the whole sweep should trigger at most one
    XLA compile (asserted at >= 4x fewer compiles than shapes — the CI
    hit-rate gate).  When ``overhead_point`` is given (a bucket-boundary
    shape), also measures bucketed vs exact-shape steady-state plan time
    there and asserts the ratio stays under
    :data:`BUCKET_OVERHEAD_CEILING`.  MUST run before anything else
    compiles planner programs, or the compile count is understated.
    """
    cfg = SchedulerConfig.green()
    cfg.local_search_rounds = rounds
    cfg.bucket = BUCKET_GRID
    sched = GreenScheduler(cfg)
    rows = []
    report("\n# Compile cache: mixed shapes, one bucket, one XLA program")
    report(f"{'S':>5} {'N':>5} {'bucket':>12} {'compiled':>9} "
           f"{'t_plan_s':>9}")
    # metrics_scope reads DELTAS of the process-global registry — no
    # reset needed, so this sweep no longer clobbers counters other
    # benchmarks (or an embedding process) may be reading
    with metrics_scope() as scope:
        for S, N in shapes:
            app, infra, comp, comm, cs = synth(S, N)
            problem = PlacementProblem.build(app, infra, comp, comm, cs)
            t0 = time.perf_counter()
            result = sched.plan(problem)
            dt = time.perf_counter() - t0
            assert result.plan.feasible
            st = result.stats
            rows.append({"S": S, "N": N,
                         "bucket": list(st.padded_shape[1:4]),
                         "compiled": st.compiled, "t_plan_s": dt})
            report(f"{S:>5} {N:>5} {str(st.padded_shape[1:4]):>12} "
                   f"{str(st.compiled):>9} {dt:>9.3f}")
    compiles = int(scope.delta("planner.compile.misses"))
    hits = int(scope.delta("planner.compile.hits"))
    compile_time_s = scope.delta("planner.compile.time_s")
    expected_hits = len(shapes) - max(1, len(shapes) // 4)
    report(f"# {len(shapes)} shapes -> {compiles} XLA compile(s), "
           f"{hits} cache hits ({compile_time_s:.1f}s compiling)")
    assert compiles * 4 <= len(shapes), (
        f"compile-cache gate: {compiles} compiles for {len(shapes)} "
        f"shapes (need >= 4x fewer)")
    assert hits >= expected_hits, (hits, expected_hits)

    out = {"bucket_grid": {"s": BUCKET_GRID.s, "f": BUCKET_GRID.f,
                           "n": BUCKET_GRID.n, "b": BUCKET_GRID.b},
           "shapes": len(shapes), "compiles": compiles, "hits": hits,
           "expected_hits": expected_hits,
           "compile_time_s": compile_time_s, "sweep": rows}

    if overhead_point is not None:
        cfg_exact = SchedulerConfig.green()
        cfg_exact.local_search_rounds = rounds
        S, N = overhead_point
        t_exact, t_bucketed = _interleaved_times(
            cfg_exact, cfg, synth(S, N), repeats)
        ratio = t_bucketed / max(t_exact, 1e-9)
        report(f"# bucketed steady-state at ({S}, {N}): "
               f"{t_bucketed*1e3:.1f}ms vs exact {t_exact*1e3:.1f}ms "
               f"-> {ratio:.2f}x (ceiling {BUCKET_OVERHEAD_CEILING}x)")
        assert ratio <= BUCKET_OVERHEAD_CEILING, (t_bucketed, t_exact)
        out["overhead"] = {"S": S, "N": N, "t_exact_s": t_exact,
                           "t_bucketed_s": t_bucketed, "ratio": ratio}
        # interior point: padding overhead when the shape is strictly
        # inside the bucket (informational, not gated — you pay for the
        # bucket you round up to)
        S_i, N_i = shapes[len(shapes) // 2]
        t_exact_i, t_bucket_i = _interleaved_times(
            cfg_exact, cfg, synth(S_i, N_i), repeats)
        out["interior_overhead"] = {
            "S": S_i, "N": N_i, "t_exact_s": t_exact_i,
            "t_bucketed_s": t_bucket_i,
            "ratio": t_bucket_i / max(t_exact_i, 1e-9)}
        report(f"# interior ({S_i}, {N_i}): bucketed "
               f"{t_bucket_i*1e3:.1f}ms vs exact {t_exact_i*1e3:.1f}ms "
               f"(informational)")
    return out


def _interleaved_times(cfg_a, cfg_b, scenario, repeats: int):
    """Best-of-``repeats`` steady-state plan time for two configs on one
    problem, ALTERNATING a/b per round so slow host drift (frequency
    scaling, background load over a long benchmark run) biases neither
    side — the overhead gate compares their ratio."""
    app, infra, comp, comm, cs = scenario
    problem = PlacementProblem.build(app, infra, comp, comm, cs)
    scheds = (GreenScheduler(cfg_a), GreenScheduler(cfg_b))
    for s in scheds:
        s.plan(problem)  # warmup: compile / prime the program cache
    best = [None, None]
    for _ in range(max(repeats, 3)):
        for i, s in enumerate(scheds):
            t0 = time.perf_counter()
            s.plan(problem)
            dt = time.perf_counter() - t0
            best[i] = dt if best[i] is None else min(best[i], dt)
    return best[0], best[1]


def run(report=print, sweep=((50, 25), (100, 50), (200, 100)),
        vec_only_sweep=((500, 200), (1000, 400)),
        sparse_points=((2000, 200),), rounds: int = 2,
        repeats: int = 3, out_json: str = OUT_JSON,
        cache_shapes=CACHE_SWEEP, overhead_point=(200, 100)):
    # the compile-cache sweep must see a cold planner cache: run it first
    cache_out = compile_cache_sweep(report, cache_shapes, rounds, repeats,
                                    overhead_point=overhead_point)
    cfg = SchedulerConfig.green()
    cfg.local_search_rounds = rounds
    rows = []
    report("# Scheduler wall time: legacy (ReferenceScheduler) vs "
           "array-native (GreenScheduler, post-compile)")
    report(f"{'S':>5} {'N':>5} {'t_ref_s':>9} {'t_vec_s':>9} "
           f"{'speedup':>8} {'J_ref':>12} {'J_vec':>12}")
    for S, N in sweep:
        app, infra, comp, comm, cs = synth(S, N)
        t_ref, ref, spent = None, None, 0.0
        for r in range(max(repeats, 1)):
            t0 = time.perf_counter()
            ref = ReferenceScheduler(cfg).plan(app, infra, comp, comm, cs)
            dt = time.perf_counter() - t0
            t_ref = dt if t_ref is None else min(t_ref, dt)
            spent += dt
            # the legacy side is interpreter-bound and fairly stable: cap
            # the CUMULATIVE time spent tightening it, only the fast jit
            # side needs full best-of-N to beat dispatch jitter
            if spent > 60.0:
                break
        problem = PlacementProblem.build(app, infra, comp, comm, cs)
        t_vec, vec = _timed_plan(cfg, problem, repeats=repeats)
        j_ref = _objective(ref, app, infra, comp, comm, cs, cfg)
        j_vec = _objective(vec, app, infra, comp, comm, cs, cfg)
        assert vec.feasible == ref.feasible
        assert j_vec <= j_ref + 1e-9 * max(1.0, abs(j_ref)), \
            (S, N, j_ref, j_vec)
        speedup = t_ref / max(t_vec, 1e-9)
        rows.append({"S": S, "N": N, "t_ref_s": t_ref, "t_vec_s": t_vec,
                     "speedup": speedup, "J_ref": j_ref, "J_vec": j_vec})
        report(f"{S:>5} {N:>5} {t_ref:>9.3f} {t_vec:>9.3f} "
               f"{speedup:>7.1f}x {j_ref:>12.3f} {j_vec:>12.3f}")

    vec_rows = []
    if vec_only_sweep:
        report("\n# Array-native only (legacy intractable at this scale)")
        report(f"{'S':>5} {'N':>5} {'t_vec_s':>9}")
    for S, N in vec_only_sweep:
        app, infra, comp, comm, cs = synth(S, N)
        problem = PlacementProblem.build(app, infra, comp, comm, cs)
        # single-shot: these rows are informational headroom, not gated
        t_vec, plan = _timed_plan(cfg, problem, repeats=1)
        assert plan.feasible
        vec_rows.append({"S": S, "N": N, "t_vec_s": t_vec,
                         "backend": problem.lowering.comm.kind})
        report(f"{S:>5} {N:>5} {t_vec:>9.3f}")

    # dense vs sparse backends must agree where both are materializable
    S, N = sweep[0]
    app, infra, comp, comm, cs = synth(S, N)
    p_d = PlacementProblem.build(app, infra, comp, comm, cs,
                                 backend="dense")
    p_s = PlacementProblem.build(app, infra, comp, comm, cs,
                                 backend="sparse")
    plan_d = GreenScheduler(cfg).plan(p_d).plan
    plan_s = GreenScheduler(cfg).plan(p_s).plan
    j_d = _objective(plan_d, app, infra, comp, comm, cs, cfg)
    j_s = _objective(plan_s, app, infra, comp, comm, cs, cfg)
    assert abs(j_d - j_s) <= 1e-9 * max(1.0, abs(j_d)), (j_d, j_s)
    report(f"\n# backend parity at ({S}, {N}): "
           f"dense J={j_d:.3f} == sparse J={j_s:.3f}")

    sparse_rows = []
    if sparse_points:
        report("\n# Sparse-comm backend (COO edge list; see dense_reported "
               "per row for whether dense was materializable)")
        report(f"{'S':>5} {'N':>5} {'links':>7} {'t_plan_s':>9} "
               f"{'dense_K_GB':>11}")
    for S, N in sparse_points:
        app, infra, comp, comm, cs = synth(S, N)
        dense_elems = S * FLAVOURS * S
        low = lower(app, infra, comp, comm, backend="sparse")
        if dense_elems > SPARSE_AUTO_THRESHOLD:
            auto = lower(app, infra, comp, comm, backend="auto")
            assert auto.comm.kind == "sparse", \
                (S, "auto-selection must pick sparse past the threshold")
        problem = PlacementProblem.build(app, infra, comp, comm, cs,
                                         lowered=low)
        t_plan, plan = _timed_plan(cfg, problem, repeats=1)
        assert plan.feasible
        dense_gb = dense_elems * 17 / 1e9  # K + derived W (f64) + has_link
        if dense_elems > SPARSE_AUTO_THRESHOLD:
            dense_reported = (
                f"infeasible to materialize: S*F*S = {dense_elems:.2e} "
                f"elements per [S,F,S] tensor > auto threshold "
                f"{SPARSE_AUTO_THRESHOLD:.2e} (K/W/has_link x B scenario "
                f"branches, plus the O(S^2*F*N) move-grid einsum)")
        else:
            dense_reported = (
                f"materializable at this size (S*F*S = {dense_elems:.2e} "
                f"<= threshold {SPARSE_AUTO_THRESHOLD:.2e}); point "
                f"exercises the sparse backend only")
        sparse_rows.append({
            "S": S, "N": N, "backend": "sparse",
            "n_links": low.comm.n_links, "t_plan_s": t_plan,
            "dense_K_elements": dense_elems,
            "dense_tensors_gb_est": dense_gb,
            "dense_reported": dense_reported,
        })
        report(f"{S:>5} {N:>5} {low.comm.n_links:>7} {t_plan:>9.3f} "
               f"{dense_gb:>11.2f}")

    top = max(rows, key=lambda r: (r["S"], r["N"]))
    report(f"\n# speedup at S={top['S']}, N={top['N']}: "
           f"{top['speedup']:.1f}x")
    # the 10x acceptance floor is defined at (S=200, N=100); only enforce
    # it when the sweep actually contains that point (quick sweeps don't)
    gate = [r for r in rows if (r["S"], r["N"]) == (200, 100)]
    if gate:
        report(f"# acceptance: {gate[0]['speedup']:.1f}x at (200, 100) "
               f"(floor {REQUIRED_SPEEDUP:.0f}x)")
        assert gate[0]["speedup"] >= REQUIRED_SPEEDUP, gate[0]

    out = {"config": {"local_search_rounds": rounds, "profile": "green",
                      "timing": "post-compile (one warmup per shape)"},
           "old_vs_vectorized": rows, "vectorized_only": vec_rows,
           "sparse_backend": sparse_rows, "compile_cache": cache_out}
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2)
        report(f"# wrote {out_json}")
    return out


def check_regression(out, baseline_path, tolerance=0.2, report=print):
    """Gate: the measured legacy-vs-array-native speedup must stay within
    ``tolerance`` of the committed baseline at every shared sweep point
    (speedup is a ratio of two runs on the SAME host, so it transfers
    across machines far better than absolute wall time)."""
    with open(baseline_path) as fh:
        base = json.load(fh)
    base_rows = {(r["S"], r["N"]): r for r in base.get("old_vs_vectorized",
                                                       [])}
    ok = True
    for r in out["old_vs_vectorized"]:
        b = base_rows.get((r["S"], r["N"]))
        if b is None:
            continue
        # plan quality first: the planner is deterministic, so the
        # objective at a committed sweep point must never regress at all
        j_ok = r["J_vec"] <= b["J_vec"] + 1e-9 * max(1.0, abs(b["J_vec"]))
        ratio = r["speedup"] / max(b["speedup"], 1e-9)
        # perf: >tolerance below the committed baseline AND below the
        # host-independent floor — a slower-but-healthy runner passes
        perf_ok = (ratio >= 1.0 - tolerance
                   or r["speedup"] >= SMOKE_SPEEDUP_FLOOR)
        verdict = "ok" if (j_ok and perf_ok) else "REGRESSED"
        report(f"# check ({r['S']}, {r['N']}): speedup {r['speedup']:.1f}x "
               f"vs baseline {b['speedup']:.1f}x -> {ratio:.2f}, "
               f"J_vec {r['J_vec']:.3f} vs {b['J_vec']:.3f} [{verdict}]")
        ok &= j_ok and perf_ok
    # compile-cache hit rate: hard-gated by the asserts inside
    # compile_cache_sweep (which runs before this on every --smoke /
    # full invocation); reported here so the --check log shows it
    cc = out.get("compile_cache")
    if cc:
        report(f"# compile cache (gated in-sweep): {cc['compiles']} "
               f"compile(s) / {cc['shapes']} shapes, {cc['hits']} hits "
               f"(expect >= {cc['expected_hits']})")
    if ok:
        report(f"# regression gate passed (tolerance {tolerance:.0%})")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI; does not overwrite the "
                         "tracked BENCH json")
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="fail if speedup regresses vs this committed "
                         "baseline by more than --tolerance")
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    enable_persistent_cache()
    if args.smoke:
        # (100, 50) with best-of-5: at (50, 25) the array-native plan is
        # ~2 ms and dispatch jitter swings the speedup ratio by 2x; at
        # (100, 50) the ~15 ms plan is stable to a few percent while the
        # legacy side still finishes in ~20 s
        out = run(sweep=((100, 50),), vec_only_sweep=(),
                  sparse_points=((600, 100),), repeats=5,
                  out_json=args.out, cache_shapes=CACHE_SWEEP_SMOKE,
                  overhead_point=(100, 50))
    else:
        out = run(out_json=args.out if args.out else OUT_JSON)
    if args.check and not check_regression(out, args.check,
                                           tolerance=args.tolerance):
        sys.exit(1)


if __name__ == "__main__":
    main()
