"""Benchmark orchestrator: one entry per paper table/figure + the roofline
table derived from the dry-run artifact.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import sys
import time


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import (
        constraint_engine,
        continuum_loop,
        explainability,
        fig2_scalability,
        fleet_scale,
        observability_overhead,
        roofline,
        scenarios,
        scheduler_savings,
        scheduler_scalability,
        table1_energy_profiles,
        table4_threshold,
    )

    suite = [
        ("table1_energy_profiles (Table 1)", table1_energy_profiles.run, {}),
        ("scenarios (Sect. 5.3)", scenarios.run, {}),
        ("explainability (Sect. 5.4)", explainability.run, {}),
        ("fig2_scalability (Fig. 2)", fig2_scalability.run,
         {"sweep": (100, 200, 400) if quick else (100, 200, 400, 700, 1000)}),
        ("table4_threshold (Table 4 / Fig. 3)", table4_threshold.run, {}),
        ("scheduler_savings (end-to-end)", scheduler_savings.run, {}),
        ("scheduler_scalability (array-native core)",
         scheduler_scalability.run,
         # quick mode skips the heavy (200,100) legacy point and must not
         # overwrite the tracked BENCH_scheduler.json with a partial sweep
         {"sweep": ((50, 25), (100, 50)),
          "vec_only_sweep": ((200, 100),),
          "sparse_points": ((600, 100),),
          "cache_shapes": scheduler_scalability.CACHE_SWEEP_SMOKE,
          "overhead_point": (100, 50),
          "out_json": None} if quick else {}),
        ("continuum_loop (adaptive loop, 7-day trace)", continuum_loop.run,
         # quick mode shortens the trace and must not overwrite the tracked
         # BENCH_continuum.json with a partial run
         {"smoke": True, "out_json": None} if quick else {}),
        ("constraint_engine (array vs reference, full vs incremental)",
         constraint_engine.run,
         # quick mode shrinks the grid and must not overwrite the tracked
         # BENCH json; runs AFTER continuum_loop so the merged
         # constraint_engine section lands on the fresh file
         {"smoke": True, "out_json": None} if quick else {}),
        ("observability_overhead (metrics/tracing/ledger gate)",
         observability_overhead.run,
         {"smoke": True, "check": True, "out_json": None} if quick else {}),
        ("fleet_scale (multi-tenant plan_many + billing)",
         fleet_scale.run,
         # quick mode shrinks the fleet and must not overwrite the
         # tracked BENCH_scheduler.json fleet section; runs AFTER
         # scheduler_scalability so the merged section lands on the
         # fresh file
         {"smoke": True, "check": True, "out_json": None} if quick else {}),
        ("roofline single-pod (§Roofline)", roofline.run, {}),
        ("roofline multi-pod (§Dry-run)", roofline.run, {"multi_pod": True}),
    ]
    failures = []
    for name, fn, kw in suite:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.perf_counter()
        try:
            fn(**kw)
            print(f"[bench OK] {name} ({time.perf_counter() - t0:.1f}s)",
                  flush=True)
        except Exception as e:  # keep going; report at the end
            failures.append((name, repr(e)))
            print(f"[bench FAIL] {name}: {e!r}", flush=True)
    print(f"\n{'=' * 72}")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: {failures}")
    print(f"all {len(suite)} benchmarks passed")


if __name__ == "__main__":
    main()
