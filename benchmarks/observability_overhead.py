"""Observability overhead gate: the unified metrics/tracing/ledger layer
must be (nearly) free.

Three checks, all self-contained ratios (no committed baseline):

* **Eager tick overhead** — the same continuum trace with a full
  ``Observability`` bundle attached vs detached, interleaved
  best-of-rounds so host drift biases neither side.  Gate: enabled wall
  time <= ``EAGER_OVERHEAD_CEILING`` x disabled.
* **Fused-path compile hygiene** — the metrics-carrying ``lax.scan``
  variant is its own XLA program (compiled once); a warm scanned run
  with the registry attached must show ZERO planner-cache misses under
  ``metrics_scope`` and zero per-tick compiles.  The scanned decisions
  must be bit-identical with and without the registry.
* **Scanned overhead** — warm scanned run enabled vs disabled.  The
  in-scan metric accumulator is 8 extra lanes on an already-fused
  program, so the ratio must stay under ``SCAN_OVERHEAD_CEILING``
  (generous: at smoke scale the scan segment is milliseconds and noisy).
* **Watchtower (detectors armed, observe mode)** — the same two ratios
  with a ``Watchtower`` + SLO engine attached (EWMA/CUSUM detector
  lanes ride the scan carry), under the same ceilings, plus the
  ``slo_watch`` accuracy gate: on the seeded fault trace every injected
  event must raise its alert within one tick, the clean trace must stay
  silent, scanned and eager alert streams must match, and the SLO
  budget must equal the ordered sum of the billing-ledger cells
  bit-for-bit.  Full runs merge the ``slo_watch`` section into
  ``BENCH_continuum.json`` next to ``fault_recovery``.

  PYTHONPATH=src python -m benchmarks.observability_overhead [--smoke]
      [--check]
"""
import argparse
import json
import os
import time

from benchmarks.jax_cache import enable_persistent_cache

from benchmarks.continuum_loop import (
    OUT_JSON as CONTINUUM_JSON,
    _carbon_planner,
    build_scenario,
)
from repro.continuum import (
    CarbonTrace,
    ContinuumRuntime,
    REGION_PRESETS,
    RuntimeConfig,
    WorkloadTrace,
)
from repro.core.pipeline import GreenConstraintPipeline
from repro.obs import Observability, SLO, Watchtower, metrics_scope

OUT_JSON = "BENCH_observability.json"
EAGER_OVERHEAD_CEILING = 1.05    # +5% on the eager tick loop
SCAN_OVERHEAD_CEILING = 1.20     # scan segment is tiny and noisy at smoke

# Which alert each seeded fault kind must raise (within one tick of the
# event's start).
ALERT_FOR_EVENT = {
    "node_outage": "node_down",
    "zone_blackout": "feed_stale",
    "telemetry_dropout": "telemetry_stale",
    "workload_spike": "energy_anomaly",
}


def _decisions(result):
    return [(r.replanned, r.switched, r.migrations, r.restarts,
             r.emissions_g, r.migration_g) for r in result.ticks]


def _fresh(app, infra, start, ticks, seed, obs, watch=False):
    rt = ContinuumRuntime(
        app, infra,
        CarbonTrace(REGION_PRESETS, hours=start + ticks + 25, seed=seed),
        WorkloadTrace(app, seed=seed),
        config=RuntimeConfig(scenarios=4, hysteresis_g=30.0),
        pipeline=GreenConstraintPipeline(), planner=_carbon_planner())
    if obs:
        rt.obs = Observability()
    if watch:
        rt.watch = Watchtower(slos=[
            SLO(name="run-budget", kind="carbon_budget",
                target=1e9, window_h=24)])
    return rt


def _interleaved(mk_a, mk_b, run, rounds):
    """Best-of-``rounds`` wall time for two runtime factories, alternating
    a/b per round so slow host drift (frequency scaling, background load)
    biases neither side."""
    best_a = best_b = None
    for _ in range(rounds):
        for which, mk in (("a", mk_a), ("b", mk_b)):
            rt = mk()
            t0 = time.perf_counter()
            run(rt)
            dt = time.perf_counter() - t0
            if which == "a":
                best_a = dt if best_a is None else min(best_a, dt)
            else:
                best_b = dt if best_b is None else min(best_b, dt)
    return best_a, best_b


def run(report=print, smoke=False, check=None, out_json=OUT_JSON, seed=0):
    check = (not smoke) if check is None else check
    start = 24
    ticks = 24 if smoke else 96
    rounds = 3 if smoke else 5
    app, infra = build_scenario()
    mk_off = lambda: _fresh(app, infra, start, ticks, seed, obs=False)
    mk_on = lambda: _fresh(app, infra, start, ticks, seed, obs=True)

    report(f"# Observability overhead: {ticks} ticks, "
           f"{len(app.services)} services, {len(infra.nodes)} nodes, "
           f"best of {rounds} interleaved rounds")

    # -- eager: full bundle attached vs detached ----------------------
    mk_off().run(start, 2)    # compile warmup: time the loop, not XLA
    res_off = mk_off().run(start, ticks)
    res_on_rt = mk_on()
    res_on = res_on_rt.run(start, ticks)
    assert _decisions(res_off) == _decisions(res_on), \
        "observability changed eager decisions"
    em_led, mig_led = res_on_rt.obs.ledger.totals()
    assert em_led == sum(r.emissions_g for r in res_on.ticks)
    assert mig_led == sum(r.migration_g for r in res_on.ticks)
    t_off, t_on = _interleaved(mk_off, mk_on, lambda rt: rt.run(start, ticks),
                               rounds)
    eager_ratio = t_on / max(t_off, 1e-9)
    report(f"  eager: disabled {t_off*1e3:.1f}ms | enabled {t_on*1e3:.1f}ms "
           f"-> {eager_ratio:.3f}x (ceiling {EAGER_OVERHEAD_CEILING}x)")

    # -- scanned: compile hygiene + decision parity + overhead --------
    mk_off().run_scanned(start, ticks)   # compile the plain scan variant
    mk_on().run_scanned(start, ticks)    # compile the metrics scan variant
    rt_w = mk_on()
    with metrics_scope() as scope:
        res_scan_on = rt_w.run_scanned(start, ticks)
    assert rt_w.last_scanned_fallback is None, rt_w.last_scanned_fallback
    warm_misses = int(scope.delta("planner.compile.misses"))
    warm_compiles = int(sum(r.compiles for r in res_scan_on.ticks))
    assert warm_misses == 0, (
        f"metrics scan recompiled in steady state: {warm_misses} misses")
    assert warm_compiles == 0, warm_compiles
    res_scan_off = mk_off().run_scanned(start, ticks)
    assert _decisions(res_scan_off) == _decisions(res_scan_on) \
        == _decisions(res_off), "observability changed scanned decisions"
    t_s_off, t_s_on = _interleaved(
        mk_off, mk_on, lambda rt: rt.run_scanned(start, ticks), rounds)
    scan_ratio = t_s_on / max(t_s_off, 1e-9)
    report(f"  scanned: disabled {t_s_off*1e3:.1f}ms | enabled "
           f"{t_s_on*1e3:.1f}ms -> {scan_ratio:.3f}x "
           f"(ceiling {SCAN_OVERHEAD_CEILING}x); warm recompiles 0")

    # -- watchtower: detectors armed in observe mode ------------------
    mk_watch = lambda: _fresh(app, infra, start, ticks, seed, obs=False,
                              watch=True)
    rt_watch = mk_watch()
    res_watch = rt_watch.run(start, ticks)
    assert _decisions(res_watch) == _decisions(res_off), \
        "observe-mode watchtower changed eager decisions"
    # budget bitwise: the SLO budget is the ordered plain sum of the
    # per-tick accounted emissions — the same cells a billing ledger
    # records and billing_report sums.
    b = 0.0
    for r in res_watch.ticks:
        b = b + (r.emissions_g + r.migration_g)
    budget_bitwise = rt_watch.watch.budget_spent_g == b
    assert budget_bitwise, (rt_watch.watch.budget_spent_g, b)
    t_woff, t_won = _interleaved(mk_off, mk_watch,
                                 lambda rt: rt.run(start, ticks), rounds)
    watch_eager_ratio = t_won / max(t_woff, 1e-9)
    report(f"  watch eager: detached {t_woff*1e3:.1f}ms | armed "
           f"{t_won*1e3:.1f}ms -> {watch_eager_ratio:.3f}x "
           f"(ceiling {EAGER_OVERHEAD_CEILING}x)")
    mk_watch().run_scanned(start, ticks)     # compile the watch variant
    rt_ws = mk_watch()
    res_watch_scan = rt_ws.run_scanned(start, ticks)
    assert rt_ws.last_scanned_fallback is None, rt_ws.last_scanned_fallback
    assert _decisions(res_watch_scan) == _decisions(res_off), \
        "observe-mode watchtower changed scanned decisions"
    assert rt_ws.watch.budget_spent_g == rt_watch.watch.budget_spent_g
    t_ws_off, t_ws_on = _interleaved(
        mk_off, mk_watch, lambda rt: rt.run_scanned(start, ticks), rounds)
    watch_scan_ratio = t_ws_on / max(t_ws_off, 1e-9)
    report(f"  watch scanned: detached {t_ws_off*1e3:.1f}ms | armed "
           f"{t_ws_on*1e3:.1f}ms -> {watch_scan_ratio:.3f}x "
           f"(ceiling {SCAN_OVERHEAD_CEILING}x)")

    acc = _alert_accuracy(report, smoke)

    out = {"ticks": ticks, "rounds": rounds,
           "eager": {"t_disabled_s": t_off, "t_enabled_s": t_on,
                     "ratio": eager_ratio,
                     "ceiling": EAGER_OVERHEAD_CEILING},
           "scanned": {"t_disabled_s": t_s_off, "t_enabled_s": t_s_on,
                       "ratio": scan_ratio,
                       "ceiling": SCAN_OVERHEAD_CEILING,
                       "warm_compile_misses": warm_misses},
           "slo_watch": {
               "eager_ratio": watch_eager_ratio,
               "eager_ceiling": EAGER_OVERHEAD_CEILING,
               "scanned_ratio": watch_scan_ratio,
               "scanned_ceiling": SCAN_OVERHEAD_CEILING,
               "budget_bitwise": budget_bitwise,
               **acc,
           }}
    if check:
        assert eager_ratio <= EAGER_OVERHEAD_CEILING, (t_on, t_off)
        assert scan_ratio <= SCAN_OVERHEAD_CEILING, (t_s_on, t_s_off)
        assert watch_eager_ratio <= EAGER_OVERHEAD_CEILING, (t_won, t_woff)
        assert watch_scan_ratio <= SCAN_OVERHEAD_CEILING, (t_ws_on, t_ws_off)
        assert acc["matched_events"] == acc["events"], acc
        assert acc["max_lag_ticks"] <= 1, acc
        assert acc["clean_false_positives"] == 0, acc
        assert acc["alert_parity_scanned"], acc
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)
        report(f"# wrote {out_json}")
        # Full runs park the accuracy section next to fault_recovery's
        # in the continuum BENCH blob (merge, don't overwrite).
        blob = {}
        if os.path.exists(CONTINUUM_JSON):
            with open(CONTINUUM_JSON) as fh:
                blob = json.load(fh)
        blob["slo_watch"] = out["slo_watch"]
        with open(CONTINUUM_JSON, "w") as fh:
            json.dump(blob, fh, indent=2)
        report(f"# merged 'slo_watch' into {CONTINUUM_JSON}")
    return out


def _alert_accuracy(report, smoke):
    """Alert accuracy on the seeded fault trace: every injected event
    must raise its mapped alert within one tick of the event start, the
    clean twin of the trace must raise nothing, and the scanned path
    must reproduce the eager alert stream exactly."""
    from benchmarks.fault_recovery import REGIONS, fault_events, make_runtime
    from repro.faults import FaultTrace

    start = 24
    ticks = 48 if smoke else 168
    app, infra = build_scenario(n_services=8, regions=REGIONS)
    events = fault_events(start, ticks)

    def mk(faulty):
        kw = dict(scenarios=4, hysteresis_g=30.0)
        if faulty:
            node_ids = tuple(n.node_id for n in infra.nodes)
            kw["faults"] = FaultTrace.from_events(
                node_ids, REGIONS, start + ticks, events)
        rt = make_runtime(
            app, infra,
            CarbonTrace(REGION_PRESETS, hours=start + ticks + 25, seed=7),
            WorkloadTrace(app, seed=11), RuntimeConfig(**kw))
        rt.watch = Watchtower()
        return rt

    rt_clean = mk(False)
    rt_clean.run(start, ticks)
    clean_fp = len(rt_clean.watch.alerts)

    rt_faulty = mk(True)
    rt_faulty.run(start, ticks)
    alerts = [(a.t, a.name, a.target) for a in rt_faulty.watch.alerts]

    matched, max_lag = 0, 0
    for ev in events:
        name = ALERT_FOR_EVENT[ev.kind]
        target = ev.target if ev.kind in ("node_outage",
                                          "zone_blackout") else None
        lags = [abs(t - ev.start) for t, n, tgt in alerts
                if n == name and abs(t - ev.start) <= 1
                and (target is None or tgt == target)]
        if lags:
            matched += 1
            max_lag = max(max_lag, min(lags))

    rt_scan = mk(True)
    rt_scan.run_scanned(start, ticks)
    parity = (rt_scan.last_scanned_fallback is None
              and [(a.t, a.name, a.target)
                   for a in rt_scan.watch.alerts] == alerts)

    report(f"  slo_watch accuracy ({ticks} ticks): "
           f"{matched}/{len(events)} events alerted (max lag {max_lag}), "
           f"{clean_fp} clean false positives, scanned parity {parity}")
    return {"accuracy_ticks": ticks, "events": len(events),
            "matched_events": matched, "max_lag_ticks": max_lag,
            "clean_false_positives": clean_fp,
            "alert_parity_scanned": parity,
            "n_alerts": len(alerts)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace, fewer rounds")
    ap.add_argument("--check", action="store_true",
                    help="enforce the overhead ceilings even under --smoke")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    enable_persistent_cache()
    run(smoke=args.smoke, check=args.check or None,
        out_json=None if (args.no_json or args.smoke) else OUT_JSON)


if __name__ == "__main__":
    main()
