"""Observability overhead gate: the unified metrics/tracing/ledger layer
must be (nearly) free.

Three checks, all self-contained ratios (no committed baseline):

* **Eager tick overhead** — the same continuum trace with a full
  ``Observability`` bundle attached vs detached, interleaved
  best-of-rounds so host drift biases neither side.  Gate: enabled wall
  time <= ``EAGER_OVERHEAD_CEILING`` x disabled.
* **Fused-path compile hygiene** — the metrics-carrying ``lax.scan``
  variant is its own XLA program (compiled once); a warm scanned run
  with the registry attached must show ZERO planner-cache misses under
  ``metrics_scope`` and zero per-tick compiles.  The scanned decisions
  must be bit-identical with and without the registry.
* **Scanned overhead** — warm scanned run enabled vs disabled.  The
  in-scan metric accumulator is 8 extra lanes on an already-fused
  program, so the ratio must stay under ``SCAN_OVERHEAD_CEILING``
  (generous: at smoke scale the scan segment is milliseconds and noisy).

  PYTHONPATH=src python -m benchmarks.observability_overhead [--smoke]
      [--check]
"""
import argparse
import json
import time

from benchmarks.jax_cache import enable_persistent_cache

from benchmarks.continuum_loop import _carbon_planner, build_scenario
from repro.continuum import (
    CarbonTrace,
    ContinuumRuntime,
    REGION_PRESETS,
    RuntimeConfig,
    WorkloadTrace,
)
from repro.core.pipeline import GreenConstraintPipeline
from repro.obs import Observability, metrics_scope

OUT_JSON = "BENCH_observability.json"
EAGER_OVERHEAD_CEILING = 1.05    # +5% on the eager tick loop
SCAN_OVERHEAD_CEILING = 1.20     # scan segment is tiny and noisy at smoke


def _decisions(result):
    return [(r.replanned, r.switched, r.migrations, r.restarts,
             r.emissions_g, r.migration_g) for r in result.ticks]


def _fresh(app, infra, start, ticks, seed, obs):
    rt = ContinuumRuntime(
        app, infra,
        CarbonTrace(REGION_PRESETS, hours=start + ticks + 25, seed=seed),
        WorkloadTrace(app, seed=seed),
        config=RuntimeConfig(scenarios=4, hysteresis_g=30.0),
        pipeline=GreenConstraintPipeline(), planner=_carbon_planner())
    if obs:
        rt.obs = Observability()
    return rt


def _interleaved(mk_a, mk_b, run, rounds):
    """Best-of-``rounds`` wall time for two runtime factories, alternating
    a/b per round so slow host drift (frequency scaling, background load)
    biases neither side."""
    best_a = best_b = None
    for _ in range(rounds):
        for which, mk in (("a", mk_a), ("b", mk_b)):
            rt = mk()
            t0 = time.perf_counter()
            run(rt)
            dt = time.perf_counter() - t0
            if which == "a":
                best_a = dt if best_a is None else min(best_a, dt)
            else:
                best_b = dt if best_b is None else min(best_b, dt)
    return best_a, best_b


def run(report=print, smoke=False, check=None, out_json=OUT_JSON, seed=0):
    check = (not smoke) if check is None else check
    start = 24
    ticks = 24 if smoke else 96
    rounds = 3 if smoke else 5
    app, infra = build_scenario()
    mk_off = lambda: _fresh(app, infra, start, ticks, seed, obs=False)
    mk_on = lambda: _fresh(app, infra, start, ticks, seed, obs=True)

    report(f"# Observability overhead: {ticks} ticks, "
           f"{len(app.services)} services, {len(infra.nodes)} nodes, "
           f"best of {rounds} interleaved rounds")

    # -- eager: full bundle attached vs detached ----------------------
    mk_off().run(start, 2)    # compile warmup: time the loop, not XLA
    res_off = mk_off().run(start, ticks)
    res_on_rt = mk_on()
    res_on = res_on_rt.run(start, ticks)
    assert _decisions(res_off) == _decisions(res_on), \
        "observability changed eager decisions"
    em_led, mig_led = res_on_rt.obs.ledger.totals()
    assert em_led == sum(r.emissions_g for r in res_on.ticks)
    assert mig_led == sum(r.migration_g for r in res_on.ticks)
    t_off, t_on = _interleaved(mk_off, mk_on, lambda rt: rt.run(start, ticks),
                               rounds)
    eager_ratio = t_on / max(t_off, 1e-9)
    report(f"  eager: disabled {t_off*1e3:.1f}ms | enabled {t_on*1e3:.1f}ms "
           f"-> {eager_ratio:.3f}x (ceiling {EAGER_OVERHEAD_CEILING}x)")

    # -- scanned: compile hygiene + decision parity + overhead --------
    mk_off().run_scanned(start, ticks)   # compile the plain scan variant
    mk_on().run_scanned(start, ticks)    # compile the metrics scan variant
    rt_w = mk_on()
    with metrics_scope() as scope:
        res_scan_on = rt_w.run_scanned(start, ticks)
    assert rt_w.last_scanned_fallback is None, rt_w.last_scanned_fallback
    warm_misses = int(scope.delta("planner.compile.misses"))
    warm_compiles = int(sum(r.compiles for r in res_scan_on.ticks))
    assert warm_misses == 0, (
        f"metrics scan recompiled in steady state: {warm_misses} misses")
    assert warm_compiles == 0, warm_compiles
    res_scan_off = mk_off().run_scanned(start, ticks)
    assert _decisions(res_scan_off) == _decisions(res_scan_on) \
        == _decisions(res_off), "observability changed scanned decisions"
    t_s_off, t_s_on = _interleaved(
        mk_off, mk_on, lambda rt: rt.run_scanned(start, ticks), rounds)
    scan_ratio = t_s_on / max(t_s_off, 1e-9)
    report(f"  scanned: disabled {t_s_off*1e3:.1f}ms | enabled "
           f"{t_s_on*1e3:.1f}ms -> {scan_ratio:.3f}x "
           f"(ceiling {SCAN_OVERHEAD_CEILING}x); warm recompiles 0")

    out = {"ticks": ticks, "rounds": rounds,
           "eager": {"t_disabled_s": t_off, "t_enabled_s": t_on,
                     "ratio": eager_ratio,
                     "ceiling": EAGER_OVERHEAD_CEILING},
           "scanned": {"t_disabled_s": t_s_off, "t_enabled_s": t_s_on,
                       "ratio": scan_ratio,
                       "ceiling": SCAN_OVERHEAD_CEILING,
                       "warm_compile_misses": warm_misses}}
    if check:
        assert eager_ratio <= EAGER_OVERHEAD_CEILING, (t_on, t_off)
        assert scan_ratio <= SCAN_OVERHEAD_CEILING, (t_s_on, t_s_off)
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)
        report(f"# wrote {out_json}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace, fewer rounds")
    ap.add_argument("--check", action="store_true",
                    help="enforce the overhead ceilings even under --smoke")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    enable_persistent_cache()
    run(smoke=args.smoke, check=args.check or None,
        out_json=None if (args.no_json or args.smoke) else OUT_JSON)


if __name__ == "__main__":
    main()
