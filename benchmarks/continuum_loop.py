"""Continuum adaptive loop over a 7-day synthetic carbon trace.

Three policies on identical carbon/workload traces:

  * ``adaptive`` — the full ContinuumRuntime: batched what-if over a
    forecast ensemble, warm-started replanning, hysteresis switching;
  * ``static``   — plan once at t0, never reconsider (what a
    deploy-and-forget scheduler does; the paper's motivation);
  * ``oracle``   — replan every tick against the TRUE future-window CI
    with no hysteresis (upper bound on temporal savings).

Also times batched (one jit/vmap call) vs sequential (B separate ``plan``
calls) what-if evaluation of the same scenario ensemble, and — on a
larger continuum (more services/nodes, where re-lowering costs real
time) — runs the adaptive loop twice over the same 7-day trace with the
per-tick delta fast path ON vs OFF: per-tick rebuild/replan wall-time
percentiles (p50/p95) and XLA compile counts land in the
``delta_replanning`` block, tick decisions must bit-match, and the
problem-rebuild p50 must drop by >= 2x.  The ``megaloop`` section rolls
the same continuum trace as one ``jit(lax.scan)`` (``run_scanned``) next
to the staged eager loop — decisions bit-matched, zero steady-state
recompiles, fused >= 5x over the staged loop — and reports the
200k-candidate (1000 x 200) point plus the lazy-``ConstraintSet``
constraint-pass p50 there.  Writes ``BENCH_continuum.json``; asserts
adaptive <= static and the speedup floors (``--check`` enforces them
under ``--smoke`` too).

  PYTHONPATH=src python -m benchmarks.continuum_loop [--smoke] [--check]
"""
import argparse
import json
import time

import numpy as np

from benchmarks.jax_cache import enable_persistent_cache

from repro.continuum import (
    CarbonTrace,
    ContinuumRuntime,
    REGION_PRESETS,
    RuntimeConfig,
    WhatIfPlanner,
    WorkloadTrace,
    monte_carlo_emissions,
)
from repro.core.lowering import ScenarioBatch
from repro.core.pipeline import GreenConstraintPipeline
from repro.core.scheduler import GreenScheduler, SchedulerConfig
from repro.core.types import (
    Application,
    CommunicationLink,
    Flavour,
    FlavourRequirements,
    Infrastructure,
    Node,
    NodeCapabilities,
    Service,
)
from repro.obs import metrics_scope

OUT_JSON = "BENCH_continuum.json"
REQUIRED_SPEEDUP = 5.0  # batched vs sequential what-if, acceptance floor
# Per-tick problem-rebuild p50 must drop by at least this factor when the
# delta fast path replaces full re-lowering (gated on the full trace).
DELTA_REBUILD_SPEEDUP = 2.0
# The fused megaloop (one jit(lax.scan) over the whole trace) vs the
# staged eager tick loop on the continuum scenario, warm program cache.
MEGALOOP_SPEEDUP = 5.0


def build_scenario(n_services=12, nodes_per_region=2,
                   regions=("solar-south", "wind-north", "coal-east")):
    """Capacity-tight continuum: the clean capacity moves with the sun, so
    a good placement at noon is a bad one at midnight."""
    services = tuple(
        Service(f"svc{i}", flavours=(
            Flavour("large", FlavourRequirements(cpu=2.0, ram_gb=4.0)),
            Flavour("small", FlavourRequirements(cpu=1.0, ram_gb=2.0)),
        )) for i in range(n_services))
    links = tuple(
        CommunicationLink(f"svc{i}", f"svc{(i + 1) % n_services}")
        for i in range(0, n_services, 2))
    app = Application("continuum-bench", services, links)
    nodes = tuple(
        Node(f"{region}-{k}", region=region, cost_per_cpu_hour=0.5,
             capabilities=NodeCapabilities(cpu=5.0, ram_gb=24.0))
        for region in regions for k in range(nodes_per_region))
    return app, Infrastructure("continuum-bench", nodes)


def _carbon_planner():
    return WhatIfPlanner(GreenScheduler(SchedulerConfig(emission_weight=1.0)))


def run_policy(name, app, infra, carbon, workload, config, start, ticks):
    runtime = ContinuumRuntime(
        app, infra, carbon, workload, config=config,
        pipeline=GreenConstraintPipeline(), planner=_carbon_planner())
    t0 = time.perf_counter()
    result = runtime.run(start=start, ticks=ticks)
    wall = time.perf_counter() - t0
    s = result.summary()
    s["wall_s"] = wall
    return result, s


def time_whatif(app, infra, carbon, workload, start, B, repeats=3):
    """Wall time of pricing the same B-branch ensemble batched (one
    jit/vmap call) vs sequentially (B separate plan() calls)."""
    pipeline = GreenConstraintPipeline()
    pipeline.gatherer.signal = carbon.history_signal(start)
    out = pipeline.run(app, infra, workload.monitoring(start))
    regions = [n.region or n.node_id for n in infra.nodes]
    scen = ScenarioBatch(ci=carbon.scenario_matrix(regions, start, B=B))
    problem = pipeline.problem_for(out).with_scenarios(scen)
    planner = _carbon_planner()

    planner.evaluate(problem)  # compile warmup
    t_batched = min(
        _timed(lambda: planner.evaluate(problem))
        for _ in range(repeats))
    t_seq = min(
        _timed(lambda: planner.evaluate_sequential(problem))
        for _ in range(repeats))
    # same ensemble, same plans — selection must agree
    rb = planner.evaluate(problem)
    rs = planner.evaluate_sequential(problem)
    assert rb.best_index == rs.best_index
    return {"B": B, "t_batched_s": t_batched, "t_sequential_s": t_seq,
            "speedup": t_seq / max(t_batched, 1e-9)}


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def time_replan_paths(report, ticks, seed=0, n_services=96,
                      nodes_per_region=16, B=4, gate=True):
    """The adaptive loop twice over the SAME trace: per-tick delta fast
    path (ci/E/K array substitution into the cached lowering) vs full
    re-lowering every tick.

    Run on a larger continuum than the emissions policies — at this
    scale the full re-lower's O(S*N) object walk costs real per-tick
    time, which is exactly what the delta path deletes.  Decisions must
    BIT-MATCH (same plans, same switches, same emissions: the
    substituted lowering is value-identical to a fresh one); the delta
    path must cut the per-tick problem-rebuild p50 by >=
    :data:`DELTA_REBUILD_SPEEDUP`.  Whole-replan (rebuild + batched
    what-if pricing) percentiles and XLA compile counts are reported for
    the same ticks.
    """
    start = 24
    app, infra = build_scenario(n_services=n_services,
                                nodes_per_region=nodes_per_region)
    carbon = CarbonTrace(REGION_PRESETS, hours=start + ticks + 25,
                         seed=seed)
    workload = WorkloadTrace(app, seed=seed)
    report(f"\n# Delta replanning: {ticks} ticks, "
           f"{len(app.services)} services, {len(infra.nodes)} nodes, "
           f"B={B} (adaptive loop, same trace, fast path on/off)")
    report(f"{'mode':>16} {'rebuild_p50':>12} {'rebuild_p95':>12} "
           f"{'replan_p50':>11} {'replan_p95':>11} {'compiles':>9}")
    # warm the jit cache for this problem shape BEFORE timing either
    # mode: otherwise whichever mode runs first pays every in-process
    # XLA compile and the cross-mode percentiles/compile counts compare
    # cache warmth, not the delta path
    warmup = ContinuumRuntime(
        app, infra, carbon, workload,
        config=RuntimeConfig(scenarios=B, hysteresis_g=30.0),
        pipeline=GreenConstraintPipeline(), planner=_carbon_planner())
    warmup.run(start=start, ticks=1)
    modes, decisions = {}, {}
    for name, delta in (("full_relower", False), ("delta_fast_path", True)):
        runtime = ContinuumRuntime(
            app, infra, carbon, workload,
            config=RuntimeConfig(scenarios=B, hysteresis_g=30.0,
                                 delta_replanning=delta),
            pipeline=GreenConstraintPipeline(), planner=_carbon_planner())
        t0 = time.perf_counter()
        result = runtime.run(start=start, ticks=ticks)
        wall = time.perf_counter() - t0
        recs = result.ticks
        rebuild = np.array([r.rebuild_s for r in recs])
        replan = np.array([r.replan_s for r in recs])
        paths = {}
        for r in recs:
            paths[r.lowering_path] = paths.get(r.lowering_path, 0) + 1
        modes[name] = {
            "ticks": len(recs),
            "rebuild_p50_ms": float(np.percentile(rebuild, 50)) * 1e3,
            "rebuild_p95_ms": float(np.percentile(rebuild, 95)) * 1e3,
            "replan_p50_ms": float(np.percentile(replan, 50)) * 1e3,
            "replan_p95_ms": float(np.percentile(replan, 95)) * 1e3,
            "xla_compiles": int(sum(r.compiles for r in recs)),
            "lowering_paths": paths,
            "wall_s": wall,
        }
        decisions[name] = [
            (r.emissions_g, r.migration_g, r.switched, r.migrations,
             r.restarts, r.expected_saving_g) for r in recs]
        m = modes[name]
        report(f"{name:>16} {m['rebuild_p50_ms']:>10.2f}ms "
               f"{m['rebuild_p95_ms']:>10.2f}ms {m['replan_p50_ms']:>9.1f}ms "
               f"{m['replan_p95_ms']:>9.1f}ms {m['xla_compiles']:>9d}")
    # identical emissions/switch decisions, tick for tick, bit for bit
    assert decisions["full_relower"] == decisions["delta_fast_path"], \
        "delta fast path changed the loop's decisions"
    speedup = (modes["full_relower"]["rebuild_p50_ms"]
               / max(modes["delta_fast_path"]["rebuild_p50_ms"], 1e-9))
    replan_speedup = (modes["full_relower"]["replan_p50_ms"]
                      / max(modes["delta_fast_path"]["replan_p50_ms"],
                            1e-9))
    report(f"# rebuild p50 speedup {speedup:.1f}x "
           f"(floor {DELTA_REBUILD_SPEEDUP:.0f}x); whole-replan p50 "
           f"{replan_speedup:.2f}x; decisions bit-matched")
    if gate:
        assert speedup >= DELTA_REBUILD_SPEEDUP, modes
    return {
        "scenario": {"ticks": ticks, "services": n_services,
                     "nodes": nodes_per_region * 3, "scenarios_B": B,
                     "seed": seed},
        "modes": modes,
        "rebuild_p50_speedup": speedup,
        "replan_p50_speedup": replan_speedup,
        "decisions_bit_match": True,
    }


def _decisions(result):
    return [(r.t, r.emissions_g, r.migration_g, r.migrations, r.switched,
             r.restarts, r.n_constraints) for r in result.ticks]


def time_megaloop(report, ticks, B, smoke, gate=True, seed=0):
    """The one-jit continuum megaloop vs the staged eager tick loop.

    Three measurements:

    * ``trace`` — the continuum scenario rolled three ways: staged eager
      ``run`` (six host round-trips per tick), ``run_scanned`` cold (pays
      the one scan compile), ``run_scanned`` warm (steady state).
      Decisions must bit-match and the warm scan must report ZERO
      planner-cache recompiles.  The gate is on the **fused replay**: the
      ``lax.scan`` segment alone (``TickRecord.replan_s`` — staging and
      commit split out) must run a full tick >= :data:`MEGALOOP_SPEEDUP`
      faster than the eager tick.  That is the number replays actually
      pay: staging is a once-per-trace cost (it mirrors the eager host
      tier exactly once to guarantee bit-parity), after which every
      re-decision over the staged tensors — steady-state re-rolls,
      ``monte_carlo_emissions`` realities — costs only the scan.  The
      marginal Monte Carlo reality is measured directly to back that up.
      End-to-end warm wall clock (stage + scan + commit) is reported,
      not gated: the one-time staging mirror bounds it near 1.5x here.
    * ``at_scale`` — the same comparison at the 200k-candidate point
      (1000 services x 200 nodes; 300 x 60 under ``--smoke``).  Reported,
      not gated at the megaloop floor: at this scale the greedy
      planner's XLA program — the IDENTICAL op sequence embedded in
      both paths — dominates even the in-scan time on few-core hosts,
      so the fused win converges to the planner-free overhead ratio.
    * ``constraint_pass`` — the lazy ``ConstraintSet`` at the
      1000 x 200, 200k-candidate point: p50 of the incremental engine
      pass consumed columnar (len/iteration stays array-native) vs the
      same pass forced through full object materialization
      (``list(out)`` — the old per-tick floor the lazy view deletes).
    """
    start = 24
    app, infra = build_scenario()

    def fresh():
        return ContinuumRuntime(
            app, infra,
            CarbonTrace(REGION_PRESETS, hours=start + ticks + 25,
                        seed=seed),
            WorkloadTrace(app, seed=seed),
            config=RuntimeConfig(scenarios=B, hysteresis_g=30.0),
            pipeline=GreenConstraintPipeline(), planner=_carbon_planner())

    report(f"\n# Megaloop: {ticks} ticks, {len(app.services)} services, "
           f"{len(infra.nodes)} nodes, B={B} "
           f"(staged eager loop vs one jit(lax.scan) over the trace)")
    results = {}

    def _run(name, fn):
        t0 = time.perf_counter()
        results[name] = fn()
        return time.perf_counter() - t0

    rt_e, rt_c, rt_w = fresh(), fresh(), fresh()
    fresh().run(start, 2)    # eager compile warmup: time the loop, not XLA
    t_eager = _run("eager", lambda: rt_e.run(start, ticks))
    t_cold = _run("cold", lambda: rt_c.run_scanned(start, ticks))
    assert rt_c.last_scanned_fallback is None, rt_c.last_scanned_fallback
    with metrics_scope() as scope:
        t_warm = _run("warm", lambda: rt_w.run_scanned(start, ticks))
    res_w = results["warm"]
    # same trace, same decisions, bit for bit — and the steady-state scan
    # reuses the compiled program (zero planner-cache recompiles, both by
    # the per-tick records and by the scoped registry delta)
    assert _decisions(results["eager"]) == _decisions(res_w) \
        == _decisions(results["cold"])
    warm_compiles = int(sum(r.compiles for r in res_w.ticks))
    assert warm_compiles == 0, warm_compiles
    warm_misses = int(scope.delta("planner.compile.misses"))
    assert warm_misses == 0, warm_misses
    speedup = t_eager / max(t_warm, 1e-9)
    # split the warm run: every TickRecord carries the amortized
    # stage/scan shares (constraint_s = stage/T, replan_s = scan/T)
    scan_s = float(sum(r.replan_s for r in res_w.ticks))
    stage_s = float(sum(r.constraint_s for r in res_w.ticks))
    eager_tick_ms = t_eager / ticks * 1e3
    replay_tick_ms = scan_s / ticks * 1e3
    replay_speedup = eager_tick_ms / max(replay_tick_ms, 1e-9)
    # the marginal cost of one more carbon reality: stage once, scan M
    # times under vmap — the purest measurement of the fused program
    monte_carlo_emissions(fresh(), start, ticks, [1.0])  # compile M=1
    mc_1 = _timed(lambda: monte_carlo_emissions(fresh(), start, ticks,
                                                [1.0]))
    monte_carlo_emissions(fresh(), start, ticks, np.ones(9))
    mc_9 = _timed(lambda: monte_carlo_emissions(fresh(), start, ticks,
                                                np.ones(9)))
    mc_marginal_ms = max(mc_9 - mc_1, 0.0) / 8 / ticks * 1e3
    report(f"  staged eager {t_eager:.2f}s | scanned cold {t_cold:.2f}s "
           f"| scanned warm {t_warm:.2f}s -> {speedup:.1f}x end-to-end "
           f"(warm recompiles 0)")
    report(f"  warm split: stage {stage_s:.2f}s (once per trace) + scan "
           f"{scan_s:.2f}s + commit {max(t_warm - stage_s - scan_s, 0.0):.2f}s")
    report(f"  fused replay {replay_tick_ms:.2f}ms/tick vs eager "
           f"{eager_tick_ms:.1f}ms/tick -> {replay_speedup:.1f}x "
           f"(floor {MEGALOOP_SPEEDUP:.0f}x); marginal Monte Carlo "
           f"reality {mc_marginal_ms:.2f}ms/tick")
    if gate:
        assert replay_speedup >= MEGALOOP_SPEEDUP, \
            (eager_tick_ms, replay_tick_ms)

    # -- the 200k-candidate point -------------------------------------
    S2, npr, t2 = (300, 20, 4) if smoke else (1000, 67, 6)
    app2, infra2 = build_scenario(n_services=S2, nodes_per_region=npr)
    cand = len(app2.services) * len(infra2.nodes)

    def fresh2():
        return ContinuumRuntime(
            app2, infra2,
            CarbonTrace(REGION_PRESETS, hours=start + t2 + 25, seed=seed),
            WorkloadTrace(app2, seed=seed),
            config=RuntimeConfig(scenarios=4, hysteresis_g=30.0),
            pipeline=GreenConstraintPipeline(), planner=_carbon_planner())

    fresh2().run(start, 2)                   # eager compile warmup
    t2_eager = _timed(lambda: fresh2().run(start, t2))
    fresh2().run_scanned(start, t2)          # scan compile warmup
    rt2_w = fresh2()
    t2_warm = _timed(lambda: rt2_w.run_scanned(start, t2))
    assert rt2_w.last_scanned_fallback is None
    at_scale_speedup = t2_eager / max(t2_warm, 1e-9)
    report(f"  at {cand // 1000}k candidates ({len(app2.services)} x "
           f"{len(infra2.nodes)}): staged {t2_eager / t2 * 1e3:.0f}ms/tick "
           f"vs scanned {t2_warm / t2 * 1e3:.0f}ms/tick -> "
           f"{at_scale_speedup:.1f}x (planner XLA shared by both paths)")

    # -- lazy ConstraintSet: the constraint pass at 200k candidates ---
    from repro.core.energy import EnergyEstimator, EnergyMixGatherer
    from repro.core.library import ConstraintLibrary
    from repro.learn.engine import ConstraintEngine
    from repro.learn.kb_array import ArrayKB

    app3, infra3 = build_scenario(n_services=1000, nodes_per_region=67)
    carbon3 = CarbonTrace(REGION_PRESETS, hours=64, seed=seed)
    workload3 = WorkloadTrace(app3, seed=seed)
    gatherer = EnergyMixGatherer()
    estimator = EnergyEstimator()
    eng = ConstraintEngine(library=ConstraintLibrary.default(),
                           kb=ArrayKB(), incremental=True)
    cand3 = len(app3.services) * len(infra3.nodes)
    t_lazy, t_mat = [], []
    for k in range(4 if smoke else 8):
        gatherer.signal = carbon3.history_signal(start + k)
        infra_e = gatherer.enrich(infra3)
        mon = workload3.monitoring(start + k)
        app_e = estimator.enrich(app3, mon)
        comp = estimator.computation_profiles(mon)
        commu = estimator.communication_profiles(mon)
        t0 = time.perf_counter()
        out = eng.run(app_e, infra_e, comp, commu, k + 1).constraints
        n_out = len(out)            # columnar: no objects materialized
        t_lazy.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        objs = list(out)            # the old floor: n_out clones
        t_mat.append(time.perf_counter() - t0)
        assert len(objs) == n_out
    lazy_p50 = float(np.percentile(t_lazy, 50)) * 1e3
    mat_p50 = float(np.percentile(np.array(t_lazy) + np.array(t_mat),
                                  50)) * 1e3
    report(f"  constraint pass at {cand3 // 1000}k candidates: lazy p50 "
           f"{lazy_p50:.1f}ms vs materialized p50 {mat_p50:.1f}ms "
           f"({mat_p50 / max(lazy_p50, 1e-9):.1f}x, {n_out} constraints)")

    return {
        "trace": {"ticks": ticks, "services": len(app.services),
                  "nodes": len(infra.nodes), "scenarios_B": B,
                  "eager_s": t_eager, "scanned_cold_s": t_cold,
                  "scanned_warm_s": t_warm, "end_to_end_speedup": speedup,
                  "stage_s": stage_s, "scan_s": scan_s,
                  "replay_tick_ms": replay_tick_ms,
                  "eager_tick_ms": eager_tick_ms,
                  "replay_speedup": replay_speedup,
                  "mc_marginal_reality_ms_per_tick": mc_marginal_ms,
                  "warm_recompiles": warm_compiles,
                  "decisions_bit_match": True},
        "at_scale": {"services": len(app2.services),
                     "nodes": len(infra2.nodes), "candidates": cand,
                     "ticks": t2, "eager_s": t2_eager,
                     "scanned_warm_s": t2_warm,
                     "speedup": at_scale_speedup},
        "constraint_pass": {"candidates": cand3,
                            "constraints_out": int(n_out),
                            "lazy_p50_ms": lazy_p50,
                            "materialized_p50_ms": mat_p50,
                            "lazy_win": mat_p50 / max(lazy_p50, 1e-9)},
    }


def run(report=print, days=7, smoke=False, check=None, out_json=OUT_JSON,
        seed=0):
    check = (not smoke) if check is None else check
    start = 24
    ticks = 48 if smoke else days * 24
    B = 4 if smoke else 8
    timing_B = 8 if smoke else 16
    app, infra = build_scenario()
    carbon = CarbonTrace(REGION_PRESETS, hours=start + ticks + 25, seed=seed)
    workload = WorkloadTrace(app, seed=seed)

    policies = {
        "adaptive": RuntimeConfig(scenarios=B, hysteresis_g=30.0),
        "static": RuntimeConfig(replan_every=10 ** 9),
        # perfect knowledge of the CI the accounting will actually charge
        # (horizon 1 = the current window), no forecast-error hysteresis
        "oracle": RuntimeConfig(oracle=True, hysteresis_g=0.0, horizon_h=1),
    }
    report(f"# Continuum loop: {ticks} ticks, {len(app.services)} services, "
           f"{len(infra.nodes)} nodes, B={B}")
    report(f"{'policy':>10} {'total_g':>12} {'operational_g':>14} "
           f"{'migration_g':>12} {'migrations':>11} {'wall_s':>8}")
    summaries = {}
    for name, config in policies.items():
        _, s = run_policy(name, app, infra, carbon, workload, config,
                          start, ticks)
        summaries[name] = s
        report(f"{name:>10} {s['total_emissions_g']:>12.1f} "
               f"{s['operational_emissions_g']:>14.1f} "
               f"{s['migration_emissions_g']:>12.1f} "
               f"{s['migrations']:>11d} {s['wall_s']:>8.2f}")

    adaptive_g = summaries["adaptive"]["total_emissions_g"]
    static_g = summaries["static"]["total_emissions_g"]
    oracle_g = summaries["oracle"]["total_emissions_g"]
    saved = 1.0 - adaptive_g / max(static_g, 1e-9)
    captured = ((static_g - adaptive_g) / max(static_g - oracle_g, 1e-9)
                if static_g > oracle_g else float("nan"))
    report(f"\n# adaptive saves {saved:.1%} vs static "
           f"(captures {captured:.1%} of the oracle headroom)")
    assert adaptive_g <= static_g, (adaptive_g, static_g)

    timing = time_whatif(app, infra, carbon, workload, start, B=timing_B)
    report(f"# what-if x{timing['B']}: batched {timing['t_batched_s']*1e3:.1f}ms "
           f"vs sequential {timing['t_sequential_s']*1e3:.1f}ms "
           f"-> {timing['speedup']:.1f}x")
    if not smoke:
        assert timing["speedup"] >= REQUIRED_SPEEDUP, timing

    # delta fast path vs full re-lowering (the >= 2x rebuild gate only on
    # the full 7-day trace: short smoke traces are jitter-dominated)
    delta = time_replan_paths(report, ticks=24 if smoke else ticks,
                              seed=seed, gate=not smoke)

    # the one-jit megaloop: always bit-match-checked; the >= 5x
    # fused-vs-staged gate when --check (or a full run) asks for it
    megaloop = time_megaloop(report, ticks=48, B=4, smoke=smoke,
                             gate=check, seed=seed)

    out = {
        "scenario": {"ticks": ticks, "services": len(app.services),
                     "nodes": len(infra.nodes), "scenarios_B": B,
                     "seed": seed},
        "policies": summaries,
        "adaptive_vs_static_saved_frac": saved,
        "oracle_headroom_captured_frac": captured,
        "whatif_timing": timing,
        "delta_replanning": delta,
        "megaloop": megaloop,
    }
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2)
        report(f"# wrote {out_json}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI; does not overwrite the "
                         "tracked BENCH json")
    ap.add_argument("--check", action="store_true",
                    help="enforce the speedup floors even under --smoke "
                         "(full runs always check)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    enable_persistent_cache()
    run(smoke=args.smoke, check=args.check or not args.smoke,
        out_json=args.out if args.out else (None if args.smoke else OUT_JSON))


if __name__ == "__main__":
    main()
