"""Continuum adaptive loop over a 7-day synthetic carbon trace.

Three policies on identical carbon/workload traces:

  * ``adaptive`` — the full ContinuumRuntime: batched what-if over a
    forecast ensemble, warm-started replanning, hysteresis switching;
  * ``static``   — plan once at t0, never reconsider (what a
    deploy-and-forget scheduler does; the paper's motivation);
  * ``oracle``   — replan every tick against the TRUE future-window CI
    with no hysteresis (upper bound on temporal savings).

Also times batched (one jit/vmap call) vs sequential (B separate ``plan``
calls) what-if evaluation of the same scenario ensemble, and — on a
larger continuum (more services/nodes, where re-lowering costs real
time) — runs the adaptive loop twice over the same 7-day trace with the
per-tick delta fast path ON vs OFF: per-tick rebuild/replan wall-time
percentiles (p50/p95) and XLA compile counts land in the
``delta_replanning`` block, tick decisions must bit-match, and the
problem-rebuild p50 must drop by >= 2x.  Writes ``BENCH_continuum.json``;
asserts adaptive <= static and the batched speedup floor.

  PYTHONPATH=src python -m benchmarks.continuum_loop [--smoke]
"""
import argparse
import json
import time

import numpy as np

from benchmarks.jax_cache import enable_persistent_cache

from repro.continuum import (
    CarbonTrace,
    ContinuumRuntime,
    REGION_PRESETS,
    RuntimeConfig,
    WhatIfPlanner,
    WorkloadTrace,
)
from repro.core.lowering import ScenarioBatch
from repro.core.pipeline import GreenConstraintPipeline
from repro.core.scheduler import GreenScheduler, SchedulerConfig
from repro.core.types import (
    Application,
    CommunicationLink,
    Flavour,
    FlavourRequirements,
    Infrastructure,
    Node,
    NodeCapabilities,
    Service,
)

OUT_JSON = "BENCH_continuum.json"
REQUIRED_SPEEDUP = 5.0  # batched vs sequential what-if, acceptance floor
# Per-tick problem-rebuild p50 must drop by at least this factor when the
# delta fast path replaces full re-lowering (gated on the full trace).
DELTA_REBUILD_SPEEDUP = 2.0


def build_scenario(n_services=12, nodes_per_region=2,
                   regions=("solar-south", "wind-north", "coal-east")):
    """Capacity-tight continuum: the clean capacity moves with the sun, so
    a good placement at noon is a bad one at midnight."""
    services = tuple(
        Service(f"svc{i}", flavours=(
            Flavour("large", FlavourRequirements(cpu=2.0, ram_gb=4.0)),
            Flavour("small", FlavourRequirements(cpu=1.0, ram_gb=2.0)),
        )) for i in range(n_services))
    links = tuple(
        CommunicationLink(f"svc{i}", f"svc{(i + 1) % n_services}")
        for i in range(0, n_services, 2))
    app = Application("continuum-bench", services, links)
    nodes = tuple(
        Node(f"{region}-{k}", region=region, cost_per_cpu_hour=0.5,
             capabilities=NodeCapabilities(cpu=5.0, ram_gb=24.0))
        for region in regions for k in range(nodes_per_region))
    return app, Infrastructure("continuum-bench", nodes)


def _carbon_planner():
    return WhatIfPlanner(GreenScheduler(SchedulerConfig(emission_weight=1.0)))


def run_policy(name, app, infra, carbon, workload, config, start, ticks):
    runtime = ContinuumRuntime(
        app, infra, carbon, workload, config=config,
        pipeline=GreenConstraintPipeline(), planner=_carbon_planner())
    t0 = time.perf_counter()
    result = runtime.run(start=start, ticks=ticks)
    wall = time.perf_counter() - t0
    s = result.summary()
    s["wall_s"] = wall
    return result, s


def time_whatif(app, infra, carbon, workload, start, B, repeats=3):
    """Wall time of pricing the same B-branch ensemble batched (one
    jit/vmap call) vs sequentially (B separate plan() calls)."""
    pipeline = GreenConstraintPipeline()
    pipeline.gatherer.signal = carbon.history_signal(start)
    out = pipeline.run(app, infra, workload.monitoring(start))
    regions = [n.region or n.node_id for n in infra.nodes]
    scen = ScenarioBatch(ci=carbon.scenario_matrix(regions, start, B=B))
    problem = pipeline.problem_for(out).with_scenarios(scen)
    planner = _carbon_planner()

    planner.evaluate(problem)  # compile warmup
    t_batched = min(
        _timed(lambda: planner.evaluate(problem))
        for _ in range(repeats))
    t_seq = min(
        _timed(lambda: planner.evaluate_sequential(problem))
        for _ in range(repeats))
    # same ensemble, same plans — selection must agree
    rb = planner.evaluate(problem)
    rs = planner.evaluate_sequential(problem)
    assert rb.best_index == rs.best_index
    return {"B": B, "t_batched_s": t_batched, "t_sequential_s": t_seq,
            "speedup": t_seq / max(t_batched, 1e-9)}


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def time_replan_paths(report, ticks, seed=0, n_services=96,
                      nodes_per_region=16, B=4, gate=True):
    """The adaptive loop twice over the SAME trace: per-tick delta fast
    path (ci/E/K array substitution into the cached lowering) vs full
    re-lowering every tick.

    Run on a larger continuum than the emissions policies — at this
    scale the full re-lower's O(S*N) object walk costs real per-tick
    time, which is exactly what the delta path deletes.  Decisions must
    BIT-MATCH (same plans, same switches, same emissions: the
    substituted lowering is value-identical to a fresh one); the delta
    path must cut the per-tick problem-rebuild p50 by >=
    :data:`DELTA_REBUILD_SPEEDUP`.  Whole-replan (rebuild + batched
    what-if pricing) percentiles and XLA compile counts are reported for
    the same ticks.
    """
    start = 24
    app, infra = build_scenario(n_services=n_services,
                                nodes_per_region=nodes_per_region)
    carbon = CarbonTrace(REGION_PRESETS, hours=start + ticks + 25,
                         seed=seed)
    workload = WorkloadTrace(app, seed=seed)
    report(f"\n# Delta replanning: {ticks} ticks, "
           f"{len(app.services)} services, {len(infra.nodes)} nodes, "
           f"B={B} (adaptive loop, same trace, fast path on/off)")
    report(f"{'mode':>16} {'rebuild_p50':>12} {'rebuild_p95':>12} "
           f"{'replan_p50':>11} {'replan_p95':>11} {'compiles':>9}")
    # warm the jit cache for this problem shape BEFORE timing either
    # mode: otherwise whichever mode runs first pays every in-process
    # XLA compile and the cross-mode percentiles/compile counts compare
    # cache warmth, not the delta path
    warmup = ContinuumRuntime(
        app, infra, carbon, workload,
        config=RuntimeConfig(scenarios=B, hysteresis_g=30.0),
        pipeline=GreenConstraintPipeline(), planner=_carbon_planner())
    warmup.run(start=start, ticks=1)
    modes, decisions = {}, {}
    for name, delta in (("full_relower", False), ("delta_fast_path", True)):
        runtime = ContinuumRuntime(
            app, infra, carbon, workload,
            config=RuntimeConfig(scenarios=B, hysteresis_g=30.0,
                                 delta_replanning=delta),
            pipeline=GreenConstraintPipeline(), planner=_carbon_planner())
        t0 = time.perf_counter()
        result = runtime.run(start=start, ticks=ticks)
        wall = time.perf_counter() - t0
        recs = result.ticks
        rebuild = np.array([r.rebuild_s for r in recs])
        replan = np.array([r.replan_s for r in recs])
        paths = {}
        for r in recs:
            paths[r.lowering_path] = paths.get(r.lowering_path, 0) + 1
        modes[name] = {
            "ticks": len(recs),
            "rebuild_p50_ms": float(np.percentile(rebuild, 50)) * 1e3,
            "rebuild_p95_ms": float(np.percentile(rebuild, 95)) * 1e3,
            "replan_p50_ms": float(np.percentile(replan, 50)) * 1e3,
            "replan_p95_ms": float(np.percentile(replan, 95)) * 1e3,
            "xla_compiles": int(sum(r.compiles for r in recs)),
            "lowering_paths": paths,
            "wall_s": wall,
        }
        decisions[name] = [
            (r.emissions_g, r.migration_g, r.switched, r.migrations,
             r.restarts, r.expected_saving_g) for r in recs]
        m = modes[name]
        report(f"{name:>16} {m['rebuild_p50_ms']:>10.2f}ms "
               f"{m['rebuild_p95_ms']:>10.2f}ms {m['replan_p50_ms']:>9.1f}ms "
               f"{m['replan_p95_ms']:>9.1f}ms {m['xla_compiles']:>9d}")
    # identical emissions/switch decisions, tick for tick, bit for bit
    assert decisions["full_relower"] == decisions["delta_fast_path"], \
        "delta fast path changed the loop's decisions"
    speedup = (modes["full_relower"]["rebuild_p50_ms"]
               / max(modes["delta_fast_path"]["rebuild_p50_ms"], 1e-9))
    replan_speedup = (modes["full_relower"]["replan_p50_ms"]
                      / max(modes["delta_fast_path"]["replan_p50_ms"],
                            1e-9))
    report(f"# rebuild p50 speedup {speedup:.1f}x "
           f"(floor {DELTA_REBUILD_SPEEDUP:.0f}x); whole-replan p50 "
           f"{replan_speedup:.2f}x; decisions bit-matched")
    if gate:
        assert speedup >= DELTA_REBUILD_SPEEDUP, modes
    return {
        "scenario": {"ticks": ticks, "services": n_services,
                     "nodes": nodes_per_region * 3, "scenarios_B": B,
                     "seed": seed},
        "modes": modes,
        "rebuild_p50_speedup": speedup,
        "replan_p50_speedup": replan_speedup,
        "decisions_bit_match": True,
    }


def run(report=print, days=7, smoke=False, out_json=OUT_JSON, seed=0):
    start = 24
    ticks = 48 if smoke else days * 24
    B = 4 if smoke else 8
    timing_B = 8 if smoke else 16
    app, infra = build_scenario()
    carbon = CarbonTrace(REGION_PRESETS, hours=start + ticks + 25, seed=seed)
    workload = WorkloadTrace(app, seed=seed)

    policies = {
        "adaptive": RuntimeConfig(scenarios=B, hysteresis_g=30.0),
        "static": RuntimeConfig(replan_every=10 ** 9),
        # perfect knowledge of the CI the accounting will actually charge
        # (horizon 1 = the current window), no forecast-error hysteresis
        "oracle": RuntimeConfig(oracle=True, hysteresis_g=0.0, horizon_h=1),
    }
    report(f"# Continuum loop: {ticks} ticks, {len(app.services)} services, "
           f"{len(infra.nodes)} nodes, B={B}")
    report(f"{'policy':>10} {'total_g':>12} {'operational_g':>14} "
           f"{'migration_g':>12} {'migrations':>11} {'wall_s':>8}")
    summaries = {}
    for name, config in policies.items():
        _, s = run_policy(name, app, infra, carbon, workload, config,
                          start, ticks)
        summaries[name] = s
        report(f"{name:>10} {s['total_emissions_g']:>12.1f} "
               f"{s['operational_emissions_g']:>14.1f} "
               f"{s['migration_emissions_g']:>12.1f} "
               f"{s['migrations']:>11d} {s['wall_s']:>8.2f}")

    adaptive_g = summaries["adaptive"]["total_emissions_g"]
    static_g = summaries["static"]["total_emissions_g"]
    oracle_g = summaries["oracle"]["total_emissions_g"]
    saved = 1.0 - adaptive_g / max(static_g, 1e-9)
    captured = ((static_g - adaptive_g) / max(static_g - oracle_g, 1e-9)
                if static_g > oracle_g else float("nan"))
    report(f"\n# adaptive saves {saved:.1%} vs static "
           f"(captures {captured:.1%} of the oracle headroom)")
    assert adaptive_g <= static_g, (adaptive_g, static_g)

    timing = time_whatif(app, infra, carbon, workload, start, B=timing_B)
    report(f"# what-if x{timing['B']}: batched {timing['t_batched_s']*1e3:.1f}ms "
           f"vs sequential {timing['t_sequential_s']*1e3:.1f}ms "
           f"-> {timing['speedup']:.1f}x")
    if not smoke:
        assert timing["speedup"] >= REQUIRED_SPEEDUP, timing

    # delta fast path vs full re-lowering (the >= 2x rebuild gate only on
    # the full 7-day trace: short smoke traces are jitter-dominated)
    delta = time_replan_paths(report, ticks=24 if smoke else ticks,
                              seed=seed, gate=not smoke)

    out = {
        "scenario": {"ticks": ticks, "services": len(app.services),
                     "nodes": len(infra.nodes), "scenarios_B": B,
                     "seed": seed},
        "policies": summaries,
        "adaptive_vs_static_saved_frac": saved,
        "oracle_headroom_captured_frac": captured,
        "whatif_timing": timing,
        "delta_replanning": delta,
    }
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2)
        report(f"# wrote {out_json}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI; does not overwrite the "
                         "tracked BENCH json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    enable_persistent_cache()
    run(smoke=args.smoke,
        out_json=args.out if args.out else (None if args.smoke else OUT_JSON))


if __name__ == "__main__":
    main()
