"""§Roofline: the per-(arch x shape) roofline table, read from the dry-run
artifact (benchmarks never re-lower; the dry-run is the single source of
truth).

  compute_s    = HLO_FLOPs / (chips x 197 TFLOP/s)
  memory_s     = HLO_bytes / (chips x 819 GB/s)
  collective_s = collective_bytes / (chips x 50 GB/s/link)

Run ``PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
--out dryrun_results.jsonl`` first (or let benchmarks.run do a reduced
sweep)."""
import json
import os

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.jsonl")


def load(path=DEFAULT_PATH):
    if not os.path.exists(path):
        return []
    recs = [json.loads(line) for line in open(path)]
    # keep the latest record per cell
    out = {}
    for r in recs:
        out[(r["arch"], r["shape"], r["multi_pod"])] = r
    return list(out.values())


def run(report=print, path=DEFAULT_PATH, multi_pod=False, tracer=None):
    """Render the roofline table.  Pass a ``repro.obs.Tracer`` to wrap
    the load + render in a ``roofline.table`` span (load time appears as
    a ``roofline.load`` child), joinable with ``dryrun.cell`` spans from
    the same tracer into one planner + launch-layer timeline."""
    if tracer is None:
        from repro.obs import Tracer
        tracer = Tracer(enabled=False)
    with tracer.span("roofline.table", multi_pod=multi_pod):
        with tracer.span("roofline.load", path=str(path)):
            recs = [r for r in load(path) if r["multi_pod"] == multi_pod]
        return _render(recs, report, path, multi_pod)


def _render(recs, report, path, multi_pod):
    if not recs:
        report(f"# no dry-run records at {path}; run repro.launch.dryrun first")
        return {"cells": 0}
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    failed = [r for r in recs if r["status"] == "error"]

    mesh = "2x16x16 (512 chips)" if multi_pod else "16x16 (256 chips)"
    report(f"# Roofline table — mesh {mesh}: {len(ok)} cells ok, "
           f"{len(skipped)} skipped (assignment-mandated), "
           f"{len(failed)} FAILED")
    hdr = (f"{'arch':<24}{'shape':<13}{'compute_s':>10}{'memory_s':>10}"
           f"{'coll_s':>10} {'bottleneck':<11}{'useful':>7}{'roof%':>7}")
    report(hdr)
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        f = r["roofline"]
        report(
            f"{r['arch']:<24}{r['shape']:<13}"
            f"{f['compute_s']:>10.4f}{f['memory_s']:>10.4f}"
            f"{f['collective_s']:>10.4f} {f['bottleneck']:<11}"
            f"{f['useful_flops_ratio']:>7.3f}"
            f"{100 * f['roofline_fraction']:>6.1f}%"
        )
    for r in skipped:
        report(f"{r['arch']:<24}{r['shape']:<13}  [skipped: sub-quadratic "
               "attention required]")
    for r in failed:
        report(f"{r['arch']:<24}{r['shape']:<13}  [FAILED: {r['error']}]")
    assert not failed, f"{len(failed)} dry-run cells failed"
    return {"cells": len(ok), "skipped": len(skipped), "failed": len(failed)}


if __name__ == "__main__":
    import sys
    run(multi_pod="--multi-pod" in sys.argv)
