"""Sect. 5.3 reproduction: constraints generated for the five scenarios,
printed in the paper's Prolog notation, with the paper's own printed
constraints checked against ours.  Also checks the array-native scheduler
against the legacy reference on every scenario (plan objective must match
or beat it)."""
import time

from repro.configs import boutique
from repro.core.pipeline import GreenConstraintPipeline
from repro.core.problem import PlacementProblem
from repro.core.scheduler import (
    GreenScheduler,
    ReferenceScheduler,
    SchedulerConfig,
    reference_objective,
)
from repro.core.types import Affinity, AvoidNode

# (scenario, service, flavour, node/other, paper weight, note)
PAPER_FACTS = [
    (1, "frontend", "large", "italy", 1.0, ""),
    (1, "frontend", "large", "greatbritain", 0.636, ""),
    (1, "productcatalog", "large", "italy", 0.499,
     "paper prints 0.446 (stale profile: 884 kWh); Eq. 11 w/ Table 1 = 0.499"),
    (2, "frontend", "large", "florida", 1.0, ""),
    (2, "frontend", "large", "washington", 0.428, ""),
    (2, "frontend", "large", "newyork", 0.414, ""),
    (2, "frontend", "large", "california", 0.412, ""),
    (3, "frontend", "large", "france", 1.0, ""),
    (4, "productcatalog", "large", "italy", 1.0, ""),
    (4, "currency", "tiny", "italy", 0.891, "paper rounds to 0.89"),
]


def run(report=print):
    t0 = time.perf_counter()
    outs = {}
    for n in range(1, 6):
        app, infra, mon = boutique.scenario(n)
        outs[n] = GreenConstraintPipeline().run(app, infra, mon, use_kb=False)
    dt_us = (time.perf_counter() - t0) * 1e6 / 5

    for n, out in outs.items():
        report(f"\n# Scenario {n} — {len(out.constraints)} constraints")
        report(out.prolog)

    checked = 0
    for n, svc, fl, node, w, note in PAPER_FACTS:
        got = {
            (c.service, c.flavour, getattr(c, "node", "")): c.weight
            for c in outs[n].constraints
        }
        actual = got[(svc, fl, node)]
        assert abs(actual - w) < 5e-3, (n, svc, node, actual, w)
        checked += 1

    s5_aff = [c for c in outs[5].constraints if isinstance(c, Affinity)]
    assert s5_aff, "Scenario 5 must surface affinity constraints"
    assert all(isinstance(c, AvoidNode) for c in outs[1].constraints), \
        "Scenario 1 affinity must be ranked out"
    report(f"\n# {checked} paper-printed weights verified; "
           f"S5 affinity surfaced: {[(c.service, c.other) for c in s5_aff]}")

    # array-native scheduler vs legacy reference on every scenario: the
    # vectorized plan's objective must match or beat the legacy plan's.
    cfg = SchedulerConfig.green()
    parity = {}
    for n, out in outs.items():
        app, infra = out.app, out.infra
        comp, comm = out.computation, out.communication
        ref = ReferenceScheduler(cfg).plan(app, infra, comp, comm,
                                           out.constraints)
        vec = GreenScheduler(cfg).plan(
            PlacementProblem.from_generator_output(out)).plan
        j = {
            k: reference_objective(
                app, infra, comp, comm, out.constraints, cfg,
                {p.service: (p.flavour, p.node) for p in plan.placements})
            for k, plan in (("ref", ref), ("vec", vec))
        }
        assert j["vec"] <= j["ref"] + 1e-9 * max(1.0, abs(j["ref"])), (n, j)
        parity[n] = j
    report(f"# scheduler parity: vectorized objective <= legacy on all "
           f"{len(parity)} scenarios")
    return {"scenarios": 5, "us_per_call": dt_us, "paper_facts": checked,
            "scheduler_parity": parity}


if __name__ == "__main__":
    run()
