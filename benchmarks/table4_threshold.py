"""Table 4 / Fig. 3 reproduction: number of generated constraints vs the
quantile threshold tau = q_alpha, on a simulated 100 services x 100 nodes
scenario with randomised-but-realistic profiles (Sect. 5.6)."""
import time

from repro.core.generator import ConstraintGenerator
from benchmarks.fig2_scalability import synth

QUANTILES = (0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60, 0.55, 0.50)
# Table 4 (paper): 85 137 227 371 636 804 1056 1164 1316 for its instance.


def run(report=print):
    app, infra, mon = synth(100, 100, seed=42)
    t0 = time.perf_counter()
    counts = []
    counts_prof = []
    impacts = {}
    for alpha in QUANTILES:
        gen = ConstraintGenerator(alpha=alpha, flavour_scope="current")
        cs = gen.generate(app, infra, mon)
        counts.append(len(cs))
        counts_prof.append(len(ConstraintGenerator(
            alpha=alpha, flavour_scope="current", tau_scope="profiles",
        ).generate(app, infra, mon)))
        impacts[alpha] = {
            kind: sorted((c.impact_g for c in cs if c.kind == kind),
                         reverse=True)
            for kind in ("avoidNode", "affinity")
        }
    dt_us = (time.perf_counter() - t0) * 1e6 / len(QUANTILES)

    report("# Table 4 — constraints vs quantile threshold "
           "(100 services x 100 nodes)")
    report("quantile            " + "  ".join(f"{q:.2f}" for q in QUANTILES))
    report("count (candidates)  " + "  ".join(f"{c}" for c in counts))
    report("count (profiles)    " + "  ".join(f"{c}" for c in counts_prof))

    # paper's structural claims:
    assert counts == sorted(counts), "lowering alpha must add constraints"
    assert counts_prof == sorted(counts_prof)
    # Eq. 5 over candidate impacts gives mechanically ~(1-alpha)N counts
    # (linear); the paper's Table 4 accelerates super-linearly, which the
    # per-profile tau reading reproduces:
    d_first = counts_prof[1] - counts_prof[0]
    d_last = counts_prof[-1] - counts_prof[-2]
    report(f"# profile-tau growth accelerates: first step +{d_first}, "
           f"last step +{d_last} (paper Table 4: +52 ... +152)")
    assert d_last > d_first, "profile-tau reading must accelerate"
    # Fig. 3: impact mass concentrates at high quantiles — within each
    # constraint type, the top-decile set holds the largest impacts (each
    # type has its own tau, so concentration is a per-type property).
    for kind in ("avoidNode", "affinity"):
        top = impacts[0.90][kind]
        rest = [x for x in impacts[0.50][kind] if x not in top]
        if top and rest:
            assert min(top) >= max(rest), (kind, min(top), max(rest))
            report(f"# Fig. 3 [{kind}]: top-decile dominates (min top "
                   f"{min(top):.0f} g >= max rest {max(rest):.0f} g)")
    return {"counts": dict(zip(QUANTILES, counts)), "us_per_call": dt_us}


if __name__ == "__main__":
    run()
