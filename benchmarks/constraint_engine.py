"""Constraint pass at continuum scale: reference trio vs array engine,
full vs dirty-mask incremental.

Drives ``ticks`` observation windows of a continuum-scale scenario
(S services x N nodes; per tick a small fraction of the Eq. 1 service
profiles and of the node carbon intensities drift — the monitoring churn
the adaptive loop actually sees) through three constraint passes over
bit-identical inputs:

  * ``reference``   — ConstraintGenerator + KBEnricher + ConstraintRanker
                      (the Sect. 4.3-4.5 object walk);
  * ``engine_full`` — ConstraintEngine(incremental=False): vectorized
                      impacts/tau/ranking, every candidate re-derived;
  * ``engine_incremental`` — ConstraintEngine(incremental=True): only the
                      dirty profile/CI slabs are re-scored and only dirty
                      survivors re-instantiated.

The ranked constraints are asserted identical across all three passes on
EVERY tick (ids, impacts, Eq. 11/12 weights, savings ranges, explanation
text, ordering) — the engines keep their own KBs, so the assertion also
covers Eq. 7-10 enrichment and mu-decay evolving in lockstep.  Per-tick
wall-time percentiles are reported over the post-warmup ticks (tick 0 is
the engines' structural rebuild); with ``--check`` the incremental pass
must beat the full pass by >= REQUIRED_SPEEDUP at p50.

Also times the TelemetryBuffer ingestion path (samples -> ring tensors ->
profiles) against the reference EnergyEstimator on the same
MonitoringData, profiles asserted equal.

Merges a ``constraint_engine`` section into BENCH_continuum.json.

  PYTHONPATH=src python -m benchmarks.constraint_engine [--smoke] [--check]
"""
import argparse
import json
import os
import time

import numpy as np

from repro.core.energy import EnergyEstimator
from repro.core.generator import ConstraintGenerator
from repro.core.kb import KBEnricher, KnowledgeBase
from repro.core.library import ConstraintLibrary
from repro.core.ranker import ConstraintRanker
from repro.core.types import (
    Application,
    EnergySample,
    Flavour,
    FlavourRequirements,
    Infrastructure,
    MonitoringData,
    Node,
    NodeCapabilities,
    Service,
    TrafficSample,
)
from repro.learn import ArrayKB, ConstraintEngine, TelemetryBuffer

OUT_JSON = "BENCH_continuum.json"
REQUIRED_SPEEDUP = 2.0  # incremental vs full engine pass, p50, gated


class DriftScenario:
    """Continuum-scale monitoring stream with sparse per-tick drift."""

    def __init__(self, S, N, L, seed=0, service_drift=0.04,
                 node_drift=0.02):
        self.S, self.N, self.L = S, N, L
        self.service_drift, self.node_drift = service_drift, node_drift
        rng = np.random.default_rng((seed, 0))
        self.seed = seed
        self.prof = rng.lognormal(mean=np.log(0.08), sigma=0.6, size=S)
        self.vol = rng.uniform(10.0, 60.0, size=L)
        self.ci = rng.uniform(60.0, 700.0, size=N)
        self.services = tuple(
            Service(f"svc{i:04d}", flavours=(
                Flavour("large", FlavourRequirements(cpu=2.0)),))
            for i in range(S))
        self.app = Application("constraint-bench", self.services)
        self.links = [(f"svc{i % S:04d}", f"svc{(i * 7 + 1) % S:04d}")
                      for i in range(L)]
        self.node_ids = [f"node{j:03d}" for j in range(N)]

    def tick(self, t):
        """Drift a sparse subset, then emit (monitoring, infra)."""
        rng = np.random.default_rng((self.seed, 1, t))
        if t > 0:
            s_idx = rng.choice(
                self.S, max(1, int(self.S * self.service_drift)),
                replace=False)
            self.prof[s_idx] *= rng.lognormal(0.0, 0.05, size=s_idx.size)
            n_idx = rng.choice(
                self.N, max(1, int(self.N * self.node_drift)),
                replace=False)
            self.ci[n_idx] = np.clip(
                self.ci[n_idx] * rng.lognormal(0.0, 0.08, size=n_idx.size),
                20.0, 900.0)
        energy = tuple(
            EnergySample(f"svc{i:04d}", "large", float(self.prof[i]), t=t)
            for i in range(self.S))
        traffic = tuple(
            TrafficSample(src, "large", dst, float(self.vol[l]), 1.0, t=t)
            for l, (src, dst) in enumerate(self.links))
        nodes = tuple(
            Node(self.node_ids[j], carbon=float(self.ci[j]),
                 capabilities=NodeCapabilities())
            for j in range(self.N))
        return (MonitoringData(energy=energy, traffic=traffic),
                Infrastructure("constraint-bench", nodes))


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q)) * 1e3


def time_telemetry(report, scen, window=6, repeats=3):
    """Windowed Eq. 1/2 profiles: TelemetryBuffer ring pooling vs the
    estimator re-walking every sample of the window.

    Per-tick profiles (``last=1``) are asserted bit-equal to the
    estimator.  For a ``window``-tick smoothing, the ring already holds
    per-tick sum/count tensors, so pooling is O(keys); the estimator has
    to re-walk all ``window * samples`` monitoring records.
    """
    import math

    est = EnergyEstimator()
    ticks = [scen.tick(t)[0] for t in range(window)]
    buf = TelemetryBuffer(window=window)
    for t, mon in enumerate(ticks):
        buf.ingest(t, mon)
    # per-tick parity: bit-equal to the estimator on the newest tick
    assert buf.computation_profiles() == \
        est.computation_profiles(ticks[-1])
    assert buf.communication_profiles() == \
        est.communication_profiles(ticks[-1])

    merged = MonitoringData(
        energy=sum((m.energy for m in ticks), ()),
        traffic=sum((m.traffic for m in ticks), ()))
    t_est = min(_timed(lambda: (est.computation_profiles(merged),
                                est.communication_profiles(merged)))
                for _ in range(repeats))
    t_tel = min(
        _timed(lambda: (buf.computation_profiles(last=window),
                        buf.communication_profiles(last=window)))
        for _ in range(repeats))
    pooled = buf.computation_profiles(last=window)
    walked = est.computation_profiles(merged)
    assert pooled.keys() == walked.keys()
    assert all(math.isclose(pooled[k], walked[k], rel_tol=1e-12)
               for k in pooled)
    speedup = t_est / max(t_tel, 1e-9)
    report(f"# telemetry {window}-tick window: estimator re-walk "
           f"{t_est * 1e3:.1f}ms vs ring pooling {t_tel * 1e3:.1f}ms "
           f"({speedup:.1f}x), per-tick profiles bit-equal")
    return {"window": window, "t_estimator_s": t_est,
            "t_telemetry_s": t_tel, "speedup": speedup,
            "profiles_equal": True}


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(report=print, S=1000, N=200, L=500, ticks=12, smoke=False,
        check=True, out_json=OUT_JSON, seed=0):
    if smoke:
        S, N, L, ticks = 300, 60, 150, 8
    scen = DriftScenario(S, N, L, seed=seed)
    est = EnergyEstimator()
    lib = ConstraintLibrary.default()

    # reference trio (own KB)
    generator = ConstraintGenerator(library=lib, estimator=est)
    enricher = KBEnricher()
    ranker = ConstraintRanker()
    ref_kb = KnowledgeBase()
    # array engines (own KBs)
    eng_full = ConstraintEngine(library=lib, kb=ArrayKB(),
                                incremental=False)
    eng_inc = ConstraintEngine(library=lib, kb=ArrayKB(), incremental=True)

    report(f"# Constraint pass: {S} services x {N} nodes "
           f"({S * N} avoidNode candidates), {L} links, {ticks} ticks, "
           f"drift {scen.service_drift:.0%} services / "
           f"{scen.node_drift:.0%} nodes per tick")
    report(f"{'tick':>5} {'reference':>11} {'full':>9} {'incr':>9} "
           f"{'dirty':>9} {'fresh':>7} {'out':>6}")
    t_ref, t_full, t_inc, dirty, n_out = [], [], [], [], []
    for t in range(ticks):
        mon, infra = scen.tick(t)
        comp = est.computation_profiles(mon)
        comm = est.communication_profiles(mon)
        it = t + 1

        t0 = time.perf_counter()
        fresh = generator.generate(scen.app, infra, mon, it)
        merged = enricher.update(ref_kb, fresh, comp, comm, infra, it)
        ref_out = ranker.rank(merged)
        t_ref.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        full_out = eng_full.run(scen.app, infra, comp, comm, it).constraints
        t_full.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        inc_out = eng_inc.run(scen.app, infra, comp, comm, it).constraints
        t_inc.append(time.perf_counter() - t0)

        # bit-identical constraints, every tick, all three passes
        assert full_out == ref_out, f"full pass diverged at tick {t}"
        assert inc_out == ref_out, f"incremental pass diverged at tick {t}"
        st = eng_inc.last_stats
        dirty.append(st.rescored)
        n_out.append(len(inc_out))
        report(f"{t:>5} {t_ref[-1] * 1e3:>9.1f}ms {t_full[-1] * 1e3:>7.1f}ms "
               f"{t_inc[-1] * 1e3:>7.1f}ms {st.rescored:>9d} "
               f"{st.fresh:>7d} {len(inc_out):>6d}")

    # percentiles over post-warmup ticks (tick 0 is the structural
    # rebuild: both engines derive every candidate there)
    sl = slice(1, None)
    modes = {
        "reference_ms": {"p50": _pct(t_ref[sl], 50),
                         "p95": _pct(t_ref[sl], 95)},
        "engine_full_ms": {"p50": _pct(t_full[sl], 50),
                           "p95": _pct(t_full[sl], 95)},
        "engine_incremental_ms": {"p50": _pct(t_inc[sl], 50),
                                  "p95": _pct(t_inc[sl], 95)},
    }
    inc_speedup = (modes["engine_full_ms"]["p50"]
                   / max(modes["engine_incremental_ms"]["p50"], 1e-9))
    ref_speedup = (modes["reference_ms"]["p50"]
                   / max(modes["engine_incremental_ms"]["p50"], 1e-9))
    report(f"\n# p50: reference {modes['reference_ms']['p50']:.1f}ms, "
           f"engine full {modes['engine_full_ms']['p50']:.1f}ms, "
           f"incremental {modes['engine_incremental_ms']['p50']:.1f}ms")
    report(f"# incremental vs full {inc_speedup:.1f}x "
           f"(floor {REQUIRED_SPEEDUP:.0f}x); vs reference "
           f"{ref_speedup:.0f}x; constraints bit-matched on all "
           f"{ticks} ticks")
    if check:
        assert inc_speedup >= REQUIRED_SPEEDUP, modes

    telemetry = time_telemetry(report, DriftScenario(S, N, L, seed=seed))

    section = {
        "scenario": {"services": S, "nodes": N, "links": L, "ticks": ticks,
                     "seed": seed, "service_drift": scen.service_drift,
                     "node_drift": scen.node_drift,
                     "avoid_candidates": S * N},
        "modes": modes,
        "incremental_vs_full_speedup": inc_speedup,
        "incremental_vs_reference_speedup": ref_speedup,
        "dirty_candidates_p50": float(np.percentile(dirty[sl], 50)),
        "constraints_per_tick_p50": float(np.percentile(n_out, 50)),
        "constraints_bit_match": True,
        "telemetry": telemetry,
    }
    if out_json:
        blob = {}
        if os.path.exists(out_json):
            with open(out_json) as fh:
                blob = json.load(fh)
        blob["constraint_engine"] = section
        with open(out_json, "w") as fh:
            json.dump(blob, fh, indent=2)
        report(f"# merged 'constraint_engine' into {out_json}")
    return section


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small scenario for CI; does not overwrite the "
                         "tracked BENCH json")
    ap.add_argument("--check", action="store_true",
                    help="gate the incremental >= 2x p50 speedup")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, check=args.check or not args.smoke,
        out_json=args.out if args.out
        else (None if args.smoke else OUT_JSON))


if __name__ == "__main__":
    main()
