"""Deployment-plan emission savings: green constraints vs the
environment-blind baseline vs the emission oracle, across all five
scenarios.  This is the end-to-end claim of the paper (validated against a
scheduler in ref. [38]; here against the built-in constraint scheduler).

The array-native scheduler produces the plans; the retained legacy
reference scheduler is run alongside on the green profile to check plan
quality (objective must match or beat) and report the speedup.
"""
import time

from repro.configs import boutique
from repro.core.pipeline import GreenConstraintPipeline
from repro.core.problem import PlacementProblem
from repro.core.scheduler import (
    GreenScheduler,
    ReferenceScheduler,
    SchedulerConfig,
    plan_emissions,
    reference_objective,
)


def _plan_emissions(plan, app, infra, comp, comm):
    assign = {p.service: (p.flavour, p.node) for p in plan.placements}
    return plan_emissions(app, infra, assign, comp, comm)


def run(report=print):
    report("# Emission savings per scenario: baseline vs +green constraints "
           "vs oracle")
    report(f"{'scenario':>9} {'baseline_g':>11} {'green_g':>10} "
           f"{'oracle_g':>10} {'saved':>7} {'of_oracle':>10}")
    out_rows = {}
    t_vec_total = t_ref_total = 0.0
    for n in range(1, 6):
        app, infra, mon = boutique.scenario(n)
        out = GreenConstraintPipeline().run(app, infra, mon, use_kb=False)
        app, infra = out.app, out.infra
        comp, comm = out.computation, out.communication
        cs = out.constraints
        problem = PlacementProblem.from_generator_output(out)
        plans = {
            "baseline": GreenScheduler(SchedulerConfig.baseline()),
            "green": GreenScheduler(SchedulerConfig.green()),
            "oracle": GreenScheduler(SchedulerConfig.oracle()),
        }
        t0 = time.perf_counter()
        solved = {k: s.plan(problem).plan for k, s in plans.items()}
        t_vec_total += time.perf_counter() - t0
        ems = {
            k: _plan_emissions(p, app, infra, comp, comm)
            for k, p in solved.items()
        }
        # legacy reference on the green profile: quality + timing check
        cfg = SchedulerConfig.green()
        t0 = time.perf_counter()
        ref = ReferenceScheduler(cfg).plan(app, infra, comp, comm, cs)
        t_ref_total += time.perf_counter() - t0
        j_ref = reference_objective(
            app, infra, comp, comm, cs, cfg,
            {p.service: (p.flavour, p.node) for p in ref.placements})
        j_vec = reference_objective(
            app, infra, comp, comm, cs, cfg,
            {p.service: (p.flavour, p.node)
             for p in solved["green"].placements})
        assert j_vec <= j_ref + 1e-9 * max(1.0, abs(j_ref)), (n, j_ref, j_vec)

        saved = 1 - ems["green"] / ems["baseline"]
        possible = ems["baseline"] - ems["oracle"]
        of_oracle = (ems["baseline"] - ems["green"]) / possible \
            if possible > 0 else 1.0
        out_rows[n] = (ems, saved, of_oracle)
        report(f"{n:>9} {ems['baseline']:>11.0f} {ems['green']:>10.0f} "
               f"{ems['oracle']:>10.0f} {100*saved:>6.1f}% "
               f"{100*of_oracle:>9.1f}%")
        assert ems["oracle"] <= ems["green"] <= ems["baseline"] + 1e-9
    mean_saved = sum(r[1] for r in out_rows.values()) / len(out_rows)
    report(f"# mean emission reduction from green constraints: "
           f"{100*mean_saved:.1f}%")
    report(f"# scheduler wall time over 5 scenarios: vectorized (3 profiles) "
           f"{t_vec_total:.3f}s, legacy (green only) {t_ref_total:.3f}s")
    assert mean_saved > 0.05, "green constraints must save emissions"
    return {n: {"saved": r[1], "of_oracle": r[2]}
            for n, r in out_rows.items()}


if __name__ == "__main__":
    run()
