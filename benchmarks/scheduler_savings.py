"""Deployment-plan emission savings: green constraints vs the
environment-blind baseline vs the emission oracle, across all five
scenarios.  This is the end-to-end claim of the paper (validated against a
scheduler in ref. [38]; here against the built-in constraint scheduler)."""
import time

from repro.configs import boutique
from repro.core.energy import EnergyEstimator, EnergyMixGatherer
from repro.core.pipeline import GreenConstraintPipeline
from repro.core.scheduler import GreenScheduler, SchedulerConfig, plan_emissions


def _plan_emissions(plan, app, infra, comp, comm):
    assign = {p.service: (p.flavour, p.node) for p in plan.placements}
    return plan_emissions(app, infra, assign, comp, comm)


def run(report=print):
    report("# Emission savings per scenario: baseline vs +green constraints "
           "vs oracle")
    report(f"{'scenario':>9} {'baseline_g':>11} {'green_g':>10} "
           f"{'oracle_g':>10} {'saved':>7} {'of_oracle':>10}")
    out_rows = {}
    for n in range(1, 6):
        app, infra, mon = boutique.scenario(n)
        est = EnergyEstimator()
        infra = EnergyMixGatherer().enrich(infra)
        comp = est.computation_profiles(mon)
        comm = est.communication_profiles(mon)
        cs = GreenConstraintPipeline().run(app, infra, mon,
                                           use_kb=False).constraints
        plans = {
            "baseline": GreenScheduler(SchedulerConfig.baseline()),
            "green": GreenScheduler(SchedulerConfig.green()),
            "oracle": GreenScheduler(SchedulerConfig.oracle()),
        }
        ems = {
            k: _plan_emissions(s.plan(app, infra, comp, comm, cs),
                               app, infra, comp, comm)
            for k, s in plans.items()
        }
        saved = 1 - ems["green"] / ems["baseline"]
        possible = ems["baseline"] - ems["oracle"]
        of_oracle = (ems["baseline"] - ems["green"]) / possible \
            if possible > 0 else 1.0
        out_rows[n] = (ems, saved, of_oracle)
        report(f"{n:>9} {ems['baseline']:>11.0f} {ems['green']:>10.0f} "
               f"{ems['oracle']:>10.0f} {100*saved:>6.1f}% "
               f"{100*of_oracle:>9.1f}%")
        assert ems["oracle"] <= ems["green"] <= ems["baseline"] + 1e-9
    mean_saved = sum(r[1] for r in out_rows.values()) / len(out_rows)
    report(f"# mean emission reduction from green constraints: "
           f"{100*mean_saved:.1f}%")
    assert mean_saved > 0.05, "green constraints must save emissions"
    return {n: {"saved": r[1], "of_oracle": r[2]}
            for n, r in out_rows.items()}


if __name__ == "__main__":
    run()
