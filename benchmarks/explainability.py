"""Sect. 5.4 reproduction: the Explainability Report for Scenario 1, with
the paper's printed savings ranges verified (within rounding of the paper's
unrounded carbon intensities)."""
import time

from repro.configs import boutique
from repro.core.pipeline import GreenConstraintPipeline

# (service, flavour, node) -> paper's printed (lo, hi) gCO2eq savings
PAPER_RANGES = {
    ("frontend", "large", "greatbritain"): (160.51, 390.38),
    ("frontend", "large", "italy"): (241.76, 632.14),
    # productcatalog/italy printed as (107.91, 282.17) from the STALE
    # 884 kWh profile; Table 1's 989 kWh gives (120.66, 315.49).
}


def run(report=print):
    app, infra, mon = boutique.scenario(1)
    t0 = time.perf_counter()
    out = GreenConstraintPipeline().run(app, infra, mon, use_kb=False)
    dt_us = (time.perf_counter() - t0) * 1e6

    report("# Explainability Report — Scenario 1 (Sect. 5.4)\n")
    report(out.report.render())

    verified = 0
    for c in out.constraints:
        key = (c.service, c.flavour, getattr(c, "node", ""))
        if key in PAPER_RANGES:
            lo_p, hi_p = PAPER_RANGES[key]
            lo, hi = c.savings_range_g
            assert abs(lo - lo_p) / lo_p < 2e-3, (key, lo, lo_p)
            assert abs(hi - hi_p) / hi_p < 2e-3, (key, hi, hi_p)
            verified += 1
    assert verified == len(PAPER_RANGES)
    report(f"\n# {verified} paper savings ranges verified to <0.2%")
    return {"us_per_call": dt_us, "ranges_verified": verified}


if __name__ == "__main__":
    run()
