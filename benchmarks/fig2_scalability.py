"""Fig. 2 reproduction: constraint-generation scalability.

(a) application-level: components swept 100..1000, nodes fixed;
(b) infrastructure-level: nodes swept 100..1000, components fixed.

The paper measures wall time (seconds) and energy (CodeCarbon).  CodeCarbon
is not installed in this container; energy is derived from measured CPU time
at a documented ~65 W single-socket busy power — same linearity conclusion,
different absolute constant."""
import random
import time

from repro.core.pipeline import GreenConstraintPipeline
from repro.core.scheduler import GreenScheduler, SchedulerConfig
from repro.core.types import (
    Application,
    EnergySample,
    Flavour,
    Infrastructure,
    MonitoringData,
    Node,
    Service,
    TrafficSample,
)

CPU_BUSY_WATTS = 65.0


def synth(n_components: int, n_nodes: int, seed: int = 0):
    rnd = random.Random(seed)
    services = tuple(
        Service(f"s{i}", flavours=(Flavour("f"),))
        for i in range(n_components)
    )
    nodes = tuple(
        Node(f"n{j}", carbon=rnd.uniform(10.0, 600.0))
        for j in range(n_nodes)
    )
    energy = tuple(
        EnergySample(f"s{i}", "f", rnd.uniform(10.0, 2000.0))
        for i in range(n_components)
    )
    traffic = tuple(
        TrafficSample(f"s{i}", "f", f"s{(i + 1) % n_components}",
                      rnd.uniform(1e3, 4e4), rnd.uniform(1e-5, 1e-3))
        for i in range(n_components)
    )
    return (Application("synth", services),
            Infrastructure("synth", nodes),
            MonitoringData(energy=energy, traffic=traffic))


def _measure(n_components, n_nodes, repeats=3):
    times = []
    counts = 0
    for r in range(repeats):
        app, infra, mon = synth(n_components, n_nodes, seed=r)
        pipe = GreenConstraintPipeline()
        t0 = time.perf_counter()
        out = pipe.run(app, infra, mon, use_kb=False)
        times.append(time.perf_counter() - t0)
        counts = len(out.constraints)
    mean = sum(times) / len(times)
    return mean, mean * CPU_BUSY_WATTS / 3600.0, counts  # s, Wh, constraints


def run(report=print, sweep=(100, 200, 400, 700, 1000)):
    report("# Fig. 2a — application-level scalability (nodes fixed at 50)")
    report(f"{'components':>11} {'time_s':>8} {'energy_Wh':>10} {'constraints':>12}")
    rows_a = []
    for n in sweep:
        t, wh, c = _measure(n, 50)
        rows_a.append((n, t))
        report(f"{n:>11} {t:>8.3f} {wh:>10.5f} {c:>12}")

    report("\n# Fig. 2b — infrastructure-level scalability (components fixed at 50)")
    report(f"{'nodes':>11} {'time_s':>8} {'energy_Wh':>10} {'constraints':>12}")
    rows_b = []
    for n in sweep:
        t, wh, c = _measure(50, n)
        rows_b.append((n, t))
        report(f"{n:>11} {t:>8.3f} {wh:>10.5f} {c:>12}")

    # paper's conclusion: seconds-scale, worst case under 120 s, growing
    # monotonically with problem size (the paper reports "approximately
    # linear"; ours carries an extra log factor from candidate sorting —
    # at 1000 components generation still takes ~2 s).
    for rows in (rows_a, rows_b):
        times = [t for _, t in rows]
        assert times == sorted(times) or max(times) < 1.0, rows
        assert times[-1] < 120.0, "paper: worst case under 120 s"

    # beyond-paper: the adaptive loop is generation + scheduling, so plan
    # time must not become the new wall at Fig. 2 scale.  The array-native
    # scheduler plans the largest sweep point in seconds.
    report("\n# scheduler plan wall time (array-native core)")
    report(f"{'components':>11} {'nodes':>6} {'plan_s':>8}")
    rows_plan = []
    for n_c, n_n in ((sweep[0], 50), (sweep[-1], 50), (50, sweep[-1])):
        app, infra, mon = synth(n_c, n_n)
        pipe = GreenConstraintPipeline()
        out = pipe.run(app, infra, mon, use_kb=False)
        t0 = time.perf_counter()
        plan = GreenScheduler(SchedulerConfig.green()).plan(
            pipe.problem_for(out)).plan
        dt = time.perf_counter() - t0
        assert plan.feasible
        rows_plan.append((n_c, n_n, dt))
        report(f"{n_c:>11} {n_n:>6} {dt:>8.3f}")
    assert all(t < 60.0 for _, _, t in rows_plan), rows_plan
    return {"app_sweep": rows_a, "infra_sweep": rows_b,
            "plan_sweep": rows_plan}


if __name__ == "__main__":
    run()
