"""Regenerate the EXPERIMENTS.md data tables from the dry-run artifacts
(single source of truth: dryrun_results.jsonl / opt_results.jsonl), plus
a green-audit section from a dumped continuum trace when one exists
(``examples/monte_carlo_traces.py --dump continuum_trace.jsonl``).

  PYTHONPATH=src python -m benchmarks.make_tables          # print all
"""
import json
import os

BASE = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.jsonl")
OPT = os.path.join(os.path.dirname(__file__), "..", "opt_results.jsonl")
TRACE = os.path.join(os.path.dirname(__file__), "..",
                     "continuum_trace.jsonl")


def load(path, multi_pod=None):
    out = {}
    for line in open(path):
        r = json.loads(line)
        if r["status"] != "ok":
            continue
        if multi_pod is not None and r["multi_pod"] != multi_pod:
            continue
        out[(r["arch"], r["shape"], r["multi_pod"])] = r
    return out


def roofline_block(report=print):
    recs = load(BASE, multi_pod=False)
    report("```")
    report(f"{'arch':<24}{'shape':<13}{'compute_s':>10}{'memory_s':>10}"
           f"{'coll_s':>10} {'bottleneck':<11}{'useful':>7}{'roof%':>7}")
    for key in sorted(recs):
        r = recs[key]
        f = r["roofline"]
        report(f"{r['arch']:<24}{r['shape']:<13}"
               f"{f['compute_s']:>10.4f}{f['memory_s']:>10.4f}"
               f"{f['collective_s']:>10.4f} {f['bottleneck']:<11}"
               f"{f['useful_flops_ratio']:>7.3f}"
               f"{100 * f['roofline_fraction']:>6.1f}%")
    report("(+ 8 long_500k cells skipped: sub-quadratic attention required)")
    report("```")


def multipod_block(report=print):
    m0 = load(BASE, multi_pod=False)
    m1 = load(BASE, multi_pod=True)
    report("| arch (train_4k) | 16x16 c/m/x | 2x16x16 c/m/x "
           "| frac 1-pod | frac 2-pod |")
    report("|---|---|---|---|---|")
    for key in sorted(m0):
        arch, shape, _ = key
        if shape != "train_4k":
            continue
        f0 = m0[key]["roofline"]
        f1 = m1[(arch, shape, True)]["roofline"]
        report(f"| {arch} | {f0['compute_s']:.2f}/{f0['memory_s']:.2f}/"
               f"{f0['collective_s']:.2f} | {f1['compute_s']:.2f}/"
               f"{f1['memory_s']:.2f}/{f1['collective_s']:.2f} | "
               f"{f0['roofline_fraction']*100:.2f}% | "
               f"{f1['roofline_fraction']*100:.2f}% |")


def optimized_block(report=print, threshold=0.03):
    base = load(BASE, multi_pod=False)
    opt = load(OPT, multi_pod=False)
    report("| arch | shape | base frac | opt frac | gain "
           "| opt bottleneck (c/m/x s) |")
    report("|---|---|---|---|---|---|")
    for key in sorted(base):
        b = base[key]["roofline"]
        o = opt.get(key, {}).get("roofline")
        if o is None or not b["roofline_fraction"]:
            continue
        g = o["roofline_fraction"] / b["roofline_fraction"]
        if abs(g - 1) < threshold:
            continue
        report(f"| {key[0]} | {key[1]} | "
               f"{100*b['roofline_fraction']:.2f}% | "
               f"{100*o['roofline_fraction']:.2f}% | {g:.1f}x | "
               f"{o['bottleneck']} ({o['compute_s']:.2f}/"
               f"{o['memory_s']:.2f}/{o['collective_s']:.2f}) |")


def green_audit_block(report=print, path=TRACE):
    """Render a dumped ContinuumResult JSONL (continuum-result/v1) as the
    run-report the observability layer produces.  Skips gracefully when
    no trace has been dumped — the audit is an optional artifact."""
    if not os.path.exists(path):
        report(f"(no continuum trace at {os.path.basename(path)} — dump "
               f"one with examples/monte_carlo_traces.py --dump)")
        return
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.continuum import ContinuumResult
    result = ContinuumResult.from_jsonl(path)
    report("```")
    report(result.render_report())
    report("```")


if __name__ == "__main__":
    print("== §Roofline baseline (single pod) ==")
    roofline_block()
    print("\n== §Dry-run multi-pod scaling (train cells) ==")
    multipod_block()
    print("\n== §Perf optimized vs baseline ==")
    optimized_block()
    print("\n== §Green audit (continuum trace) ==")
    green_audit_block()
