"""Regenerate the EXPERIMENTS.md data tables from the dry-run artifacts
(single source of truth: dryrun_results.jsonl / opt_results.jsonl), plus
a green-audit section from a dumped continuum trace when one exists
(``examples/monte_carlo_traces.py --dump continuum_trace.jsonl``).

  PYTHONPATH=src python -m benchmarks.make_tables          # print all
"""
import json
import os

BASE = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.jsonl")
OPT = os.path.join(os.path.dirname(__file__), "..", "opt_results.jsonl")
TRACE = os.path.join(os.path.dirname(__file__), "..",
                     "continuum_trace.jsonl")


def load(path, multi_pod=None):
    out = {}
    for line in open(path):
        r = json.loads(line)
        if r["status"] != "ok":
            continue
        if multi_pod is not None and r["multi_pod"] != multi_pod:
            continue
        out[(r["arch"], r["shape"], r["multi_pod"])] = r
    return out


def roofline_block(report=print):
    recs = load(BASE, multi_pod=False)
    report("```")
    report(f"{'arch':<24}{'shape':<13}{'compute_s':>10}{'memory_s':>10}"
           f"{'coll_s':>10} {'bottleneck':<11}{'useful':>7}{'roof%':>7}")
    for key in sorted(recs):
        r = recs[key]
        f = r["roofline"]
        report(f"{r['arch']:<24}{r['shape']:<13}"
               f"{f['compute_s']:>10.4f}{f['memory_s']:>10.4f}"
               f"{f['collective_s']:>10.4f} {f['bottleneck']:<11}"
               f"{f['useful_flops_ratio']:>7.3f}"
               f"{100 * f['roofline_fraction']:>6.1f}%")
    report("(+ 8 long_500k cells skipped: sub-quadratic attention required)")
    report("```")


def multipod_block(report=print):
    m0 = load(BASE, multi_pod=False)
    m1 = load(BASE, multi_pod=True)
    report("| arch (train_4k) | 16x16 c/m/x | 2x16x16 c/m/x "
           "| frac 1-pod | frac 2-pod |")
    report("|---|---|---|---|---|")
    for key in sorted(m0):
        arch, shape, _ = key
        if shape != "train_4k":
            continue
        f0 = m0[key]["roofline"]
        f1 = m1[(arch, shape, True)]["roofline"]
        report(f"| {arch} | {f0['compute_s']:.2f}/{f0['memory_s']:.2f}/"
               f"{f0['collective_s']:.2f} | {f1['compute_s']:.2f}/"
               f"{f1['memory_s']:.2f}/{f1['collective_s']:.2f} | "
               f"{f0['roofline_fraction']*100:.2f}% | "
               f"{f1['roofline_fraction']*100:.2f}% |")


def optimized_block(report=print, threshold=0.03):
    base = load(BASE, multi_pod=False)
    opt = load(OPT, multi_pod=False)
    report("| arch | shape | base frac | opt frac | gain "
           "| opt bottleneck (c/m/x s) |")
    report("|---|---|---|---|---|---|")
    for key in sorted(base):
        b = base[key]["roofline"]
        o = opt.get(key, {}).get("roofline")
        if o is None or not b["roofline_fraction"]:
            continue
        g = o["roofline_fraction"] / b["roofline_fraction"]
        if abs(g - 1) < threshold:
            continue
        report(f"| {key[0]} | {key[1]} | "
               f"{100*b['roofline_fraction']:.2f}% | "
               f"{100*o['roofline_fraction']:.2f}% | {g:.1f}x | "
               f"{o['bottleneck']} ({o['compute_s']:.2f}/"
               f"{o['memory_s']:.2f}/{o['collective_s']:.2f}) |")


def green_audit_block(report=print, path=TRACE):
    """Render a dumped ContinuumResult JSONL (continuum-result/v1) as the
    run-report the observability layer produces.  Skips gracefully when
    no trace has been dumped — the audit is an optional artifact."""
    if not os.path.exists(path):
        report(f"(no continuum trace at {os.path.basename(path)} — dump "
               f"one with examples/monte_carlo_traces.py --dump)")
        return
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.continuum import ContinuumResult
    result = ContinuumResult.from_jsonl(path)
    report("```")
    report(result.render_report())
    report("```")


SCHED = os.path.join(os.path.dirname(__file__), "..",
                     "BENCH_scheduler.json")


def fleet_billing_block(report=print, path=SCHED):
    """Render the per-tenant billing table and fleet-scale sweep from the
    ``fleet`` section of ``BENCH_scheduler.json``.  Skips gracefully when
    the section is absent (the fleet benchmark hasn't run full yet)."""
    if not os.path.exists(path):
        report(f"(no {os.path.basename(path)} — run "
               f"benchmarks.fleet_scale first)")
        return
    with open(path) as fh:
        blob = json.load(fh)
    fleet = blob.get("fleet")
    if not fleet:
        report("(no 'fleet' section in BENCH_scheduler.json — run "
               "benchmarks.fleet_scale without --smoke)")
        return
    report("```")
    report(f"{'apps':>6}{'uncoupled_s':>13}{'waterfill_s':>13}"
           f"{'ms/app(wf)':>12}{'wf_viol':>9}{'unc_viol':>9}"
           f"{'feasible':>10}")
    for row in fleet["sweep"]:
        wf, unc = row["waterfill"], row["uncoupled"]
        report(f"{row['apps']:>6}{unc['plan_s']:>13.3f}"
               f"{wf['plan_s']:>13.3f}{wf['per_app_ms']:>12.2f}"
               f"{wf['violations']:>9}{unc['violations']:>9}"
               f"{wf['feasible']:>9}/{row['apps']}")
    report(f"cold XLA programs: {fleet['cold_compiles']} "
           f"(ceiling {fleet['compile_ceiling']})")
    billing = fleet.get("billing", {})
    rows = billing.get("rows", {})
    if rows:
        report(f"\n{'tenant':<12}{'comp_g':>10}{'comm_g':>10}"
               f"{'migration_g':>12}{'total_g':>10}{'ticks':>7}")
        for tenant, r in sorted(rows.items(),
                                key=lambda kv: -kv[1]["total"]):
            report(f"{tenant:<12}{r.get('comp', 0.0):>10.3f}"
                   f"{r.get('comm', 0.0):>10.3f}"
                   f"{r.get('migration', 0.0):>12.3f}"
                   f"{r['total']:>10.3f}{int(r.get('ticks', 0)):>7}")
        report(f"bit-exact decomposition: {billing.get('bit_exact')}")
    report("```")


if __name__ == "__main__":
    print("== §Roofline baseline (single pod) ==")
    roofline_block()
    print("\n== §Dry-run multi-pod scaling (train cells) ==")
    multipod_block()
    print("\n== §Perf optimized vs baseline ==")
    optimized_block()
    print("\n== §Green audit (continuum trace) ==")
    green_audit_block()
    print("\n== §Fleet planning (multi-tenant billing) ==")
    fleet_billing_block()
