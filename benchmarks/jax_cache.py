"""Opt-in persistent XLA compilation cache for benchmarks and CI.

When ``JAX_COMPILATION_CACHE_DIR`` is set, compiled planner programs are
serialized there and reloaded on the next process start — so CI (and any
repeated local benchmarking) stops paying the multi-second cold compile
for shapes it has already built.  Pairs with the shape-bucketed planner
cache: bucketing keeps the number of DISTINCT programs small, persistence
keeps them warm across processes.
"""
import os


def enable_persistent_cache(report=print) -> bool:
    """Point jax's compilation cache at ``$JAX_COMPILATION_CACHE_DIR``.

    Returns True when enabled.  No-op (False) when the variable is unset
    or this jax build lacks the config knobs.
    """
    cache_dir = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if not cache_dir:
        return False
    import jax

    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
    except Exception as exc:  # pragma: no cover — very old jax
        report(f"# persistent compilation cache unavailable: {exc}")
        return False
    # cache every program, however small/fast-compiling (defaults skip
    # sub-second compiles — most of the smoke-suite programs)
    for knob, value in (
            ("jax_persistent_cache_min_compile_time_secs", 0.0),
            ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(knob, value)
        except Exception:  # knob name drift across jax versions
            pass
    report(f"# persistent XLA compilation cache: {cache_dir}")
    return True
