"""§Perf hillclimb driver: lower one cell with tuning overrides, print the
three roofline terms + the top-bytes breakdown.

  PYTHONPATH=src python -m benchmarks.perf_iterate --arch qwen2-1.5b \
      --shape train_4k --set seq_parallel_attn=True remat_chunk_attn=True
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax

from repro.launch import hlo_cost, hlo_analysis
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.plan import build_plan


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def lower_cell(arch, shape, overrides, multi_pod=False):
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = build_plan(arch, shape, multi_pod=multi_pod,
                      tuning_overrides=overrides or None)
    with mesh_context(mesh):
        compiled = plan.lower().compile()
        txt = compiled.as_text()
        mem = compiled.memory_analysis()
    totals = hlo_cost.analyze(txt)
    roof = hlo_analysis.Roofline(
        flops=totals.flops, hbm_bytes=totals.bytes,
        coll_bytes=totals.coll_bytes, model_flops=plan.model_flops,
        chips=plan.chips)
    return dict(
        compile_s=round(time.time() - t0, 1),
        peak_gib=(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                  + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**30,
        roof=roof, txt=txt, coll=totals.coll_bytes_by_kind,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--breakdown", type=int, default=12)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    overrides = parse_overrides(args.set)
    res = lower_cell(args.arch, args.shape, overrides, args.multi_pod)
    r = res["roof"]
    print(f"== {args.arch} x {args.shape} "
          f"{'2x16x16' if args.multi_pod else '16x16'} overrides={overrides}")
    print(f"compile {res['compile_s']}s  peak {res['peak_gib']:.2f} GiB/dev")
    print(f"compute_s    {r.compute_s:10.4f}")
    print(f"memory_s     {r.memory_s:10.4f}")
    print(f"collective_s {r.collective_s:10.4f}   ({ {k: f'{v/1e9:.1f}GB' for k, v in res['coll'].items()} })")
    print(f"bottleneck   {r.bottleneck}   useful {r.useful_flops_ratio:.3f}"
          f"   roofline_fraction {r.roofline_fraction:.4f}")
    if args.breakdown:
        by_op, top = hlo_cost.breakdown(res["txt"], top=args.breakdown)
        print("-- top byte contributors --")
        for b, op, comp, name in top:
            print(f"  {b/1e9:9.1f} GB  {op:<12} {comp[:34]}/{name[:52]}")
    if args.save_hlo:
        open(args.save_hlo, "w").write(res["txt"])


if __name__ == "__main__":
    main()
