"""Fault recovery on the continuum: 7-day faulty trace, four policies.

A seeded :class:`repro.faults.FaultTrace` (node outages that strand the
green placements, a carbon-zone blackout, a telemetry dropout, a
workload spike) is replayed against four policies on IDENTICAL carbon /
workload traces:

  * ``faulty_adaptive``     — full runtime with emergency replanning:
    stranded services are evicted and re-placed the same tick, bypassing
    the hysteresis gate (migration costs still billed);
  * ``faulty_no_emergency`` — same faults, emergency replanning off:
    evictions still happen, but re-adoption waits for the ordinary
    hysteresis gate — the downtime baseline;
  * ``fault_free``          — same adaptive config, no faults (what the
    outages cost in emissions and migrations);
  * ``faulty_oracle``       — fault-aware oracle: sees the faults, prices
    the TRUE future window, no hysteresis (upper bound under faults).

Gates (``--check``; full runs always check):

  * the trace actually exercises the fault model (>= 3 node outages,
    >= 1 zone blackout, >= 1 telemetry dropout);
  * ZERO post-plan invariant violations (dead-node / over-capacity
    placements) on every policy — the validator runs inside each tick;
  * recovery-to-feasible <= 1 tick with emergency replanning: every
    eviction tick re-places the stranded services in that same tick;
  * eager vs ``run_scanned`` bit-parity on the faulty trace (outages,
    blackout, dropout, spike are all value-level faults): every decision
    and accounting field identical, ``expected_saving_g`` to 1e-9, no
    fallback;
  * capacity derates are STRUCTURAL: ``run_scanned`` on a derated trace
    must fall back loudly with exactly one
    ``FallbackReason.FAULT_CAPACITY_DERATE`` event and replay eagerly
    with zero violations.

Merges a ``fault_recovery`` section into ``BENCH_continuum.json``.

  PYTHONPATH=src python -m benchmarks.fault_recovery [--smoke] [--check]
"""
import argparse
import json
import os
import time

import numpy as np

from benchmarks.jax_cache import enable_persistent_cache
from benchmarks.continuum_loop import OUT_JSON, _carbon_planner, build_scenario

from repro.continuum import (
    CarbonTrace,
    ContinuumRuntime,
    FallbackReason,
    REGION_PRESETS,
    RuntimeConfig,
    WorkloadTrace,
)
from repro.core.pipeline import GreenConstraintPipeline
from repro.faults import FaultEvent, FaultTrace

REGIONS = ("solar-south", "wind-north", "coal-east")
# Decision/accounting fields that must be IDENTICAL between the eager
# and scanned paths on a value-level faulty trace.  expected_saving_g is
# compared to 1e-9 instead: XLA and numpy may disagree in the last ulp
# on non-dyadic degraded-carbon values (every decision derived from it
# is still exact).
EXACT_FIELDS = ("t", "emissions_g", "migration_g", "migrations",
                "replanned", "switched", "restarts", "n_constraints",
                "warm_start_rejected", "evicted", "emergency",
                "violations")
MAX_RECOVERY_TICKS = 1


def fault_events(start, ticks):
    """Deterministic schedule aimed at the green placements: the carbon
    planner parks services on wind-north (lowest CI), so the outages
    must hit wind-north nodes to actually strand services.  The two
    wind-north outages overlap, forcing a full evacuation of the clean
    region for a few ticks."""
    t0 = start
    ev = [
        FaultEvent("node_outage", "wind-north-0", t0 + 11, 8),
        FaultEvent("node_outage", "wind-north-1", t0 + 14, 4),
        FaultEvent("node_outage", "solar-south-0", t0 + 26, 3),
        FaultEvent("zone_blackout", "wind-north", t0 + 16, 6),
        FaultEvent("telemetry_dropout", "", t0 + 34, 3),
        FaultEvent("workload_spike", "", t0 + 30, 4, 2.0),
    ]
    if ticks >= 96:  # the full week gets a second round of weather
        ev += [
            FaultEvent("node_outage", "wind-north-1", t0 + 96, 6),
            FaultEvent("node_outage", "coal-east-0", t0 + 120, 5),
            FaultEvent("zone_blackout", "solar-south", t0 + 110, 12),
            FaultEvent("telemetry_dropout", "", t0 + 140, 4),
        ]
    return [e for e in ev if e.start + e.hours <= start + ticks]


def make_runtime(app, infra, carbon, workload, config):
    return ContinuumRuntime(
        app, infra, carbon, workload, config=config,
        pipeline=GreenConstraintPipeline(), planner=_carbon_planner())


def recovery_ticks(records):
    """Per eviction tick: 1 if the stranded services were re-placed by a
    plan switch in that same tick, else 1 + ticks until the next switch
    (censored at end of trace).  "Feasible again within the tick the
    fault landed" reads as 1."""
    out = []
    for i, r in enumerate(records):
        if r.evicted <= 0:
            continue
        lag = next((j for j, rr in enumerate(records[i:]) if rr.switched),
                   len(records) - i)
        out.append(1 + lag if lag else 1)
    return out


def run_policies(report, app, infra, carbon, workload, faults, start,
                 ticks, B):
    configs = {
        "faulty_adaptive": RuntimeConfig(
            scenarios=B, hysteresis_g=30.0, faults=faults),
        "faulty_no_emergency": RuntimeConfig(
            scenarios=B, hysteresis_g=30.0, faults=faults,
            emergency_replan=False),
        "fault_free": RuntimeConfig(scenarios=B, hysteresis_g=30.0),
        "faulty_oracle": RuntimeConfig(
            oracle=True, hysteresis_g=0.0, horizon_h=1, faults=faults),
    }
    report(f"{'policy':>20} {'emissions_g':>12} {'migr_g':>8} "
           f"{'migs':>5} {'evict':>6} {'emerg':>6} {'viol':>5} "
           f"{'recovery':>9}")
    rows = {}
    for name, cfg in configs.items():
        rt = make_runtime(app, infra, carbon, workload, cfg)
        t0 = time.perf_counter()
        res = rt.run(start=start, ticks=ticks)
        wall = time.perf_counter() - t0
        recs = res.ticks
        rec = recovery_ticks(recs)
        rows[name] = {
            **res.summary(),
            "evicted": sum(r.evicted for r in recs),
            "emergencies": sum(r.emergency for r in recs),
            "violations": len(rt.placement_violations),
            "recovery_ticks": rec,
            "max_recovery_ticks": max(rec) if rec else 0,
            "wall_s": wall,
        }
        r = rows[name]
        report(f"{name:>20} {r['total_emissions_g']:>12.1f} "
               f"{r['migration_emissions_g']:>8.1f} {r['migrations']:>5} "
               f"{r['evicted']:>6} {r['emergencies']:>6} "
               f"{r['violations']:>5} {r['max_recovery_ticks']:>9}")
    return rows


def parity_run(report, app, infra, carbon, workload, faults, start,
               ticks, B):
    """Eager vs scanned on the SAME faulty trace: every fault here is
    value-level (no derates), so run_scanned must stay on the fused path
    and bit-match the eager loop."""
    mk = lambda: make_runtime(  # noqa: E731
        app, infra, carbon, workload,
        RuntimeConfig(scenarios=B, hysteresis_g=30.0, faults=faults))
    rt_e, rt_s = mk(), mk()
    res_e = rt_e.run(start=start, ticks=ticks)
    res_s = rt_s.run_scanned(start=start, ticks=ticks)
    mismatches = []
    for re_, rs_ in zip(res_e.ticks, res_s.ticks):
        for f in EXACT_FIELDS:
            if getattr(re_, f) != getattr(rs_, f):
                mismatches.append((re_.t, f))
    savings_e = np.array([r.expected_saving_g for r in res_e.ticks])
    savings_s = np.array([r.expected_saving_g for r in res_s.ticks])
    saving_close = bool(np.allclose(savings_e, savings_s, rtol=1e-9,
                                    atol=1e-9))
    out = {
        "ticks": ticks,
        "mismatched_fields": len(mismatches),
        "saving_close_1e9": saving_close,
        "fallbacks": len(rt_s.scanned_fallbacks),
        "final_assignment_equal":
            res_e.final_assignment == res_s.final_assignment,
        "violations_eager": len(rt_e.placement_violations),
        "violations_scanned": len(rt_s.placement_violations),
    }
    report(f"  eager vs scanned on the faulty trace: "
           f"{out['mismatched_fields']} field mismatches, "
           f"saving<=1e-9: {saving_close}, "
           f"fallbacks: {out['fallbacks']}, violations: "
           f"{out['violations_eager']}/{out['violations_scanned']}")
    return out


def derate_fallback_run(report, app, infra, carbon, workload, start,
                        ticks, B):
    """Capacity derates change the capacity tensors mid-trace, which the
    fused scan treats as constants: run_scanned must refuse the fused
    path with ONE structured FAULT_CAPACITY_DERATE event and replay the
    whole window eagerly — still fault-aware, still validated."""
    node_ids = [n.node_id for n in infra.nodes]
    ft = FaultTrace.from_events(
        node_ids, REGIONS, start + ticks,
        [FaultEvent("capacity_derate", "wind-north-0",
                    start + ticks // 3, 6, 0.5)])
    rt = make_runtime(app, infra, carbon, workload,
                      RuntimeConfig(scenarios=B, hysteresis_g=30.0,
                                    faults=ft))
    res = rt.run_scanned(start=start, ticks=ticks)
    evs = rt.scanned_fallbacks
    out = {
        "ticks": len(res.ticks),
        "fallback_events": len(evs),
        "reason": str(evs[0].reason) if evs else None,
        "reason_is_derate":
            bool(evs) and evs[0].reason is FallbackReason.FAULT_CAPACITY_DERATE,
        "violations": len(rt.placement_violations),
    }
    report(f"  derated trace: {out['fallback_events']} fallback "
           f"(reason: {out['reason']}), eager replay {out['ticks']} "
           f"ticks, {out['violations']} violations")
    return out


def run(report=print, smoke=False, check=None, out_json=OUT_JSON):
    check = True if check is None else check
    start = 24
    ticks = 48 if smoke else 168
    B = 4 if smoke else 8
    n_services = 8

    app, infra = build_scenario(n_services=n_services, regions=REGIONS)
    node_ids = [n.node_id for n in infra.nodes]
    carbon = CarbonTrace(REGION_PRESETS, hours=start + ticks + 25, seed=7)
    workload = WorkloadTrace(app, seed=11)
    events = fault_events(start, ticks)
    faults = FaultTrace.from_events(node_ids, REGIONS, start + ticks,
                                    events)
    kinds = {k: sum(e.kind == k for e in faults.events)
             for k in ("node_outage", "zone_blackout",
                       "telemetry_dropout", "workload_spike")}
    report(f"# Fault recovery: {ticks} ticks, {n_services} services, "
           f"{len(node_ids)} nodes, faults: {kinds}")

    rows = run_policies(report, app, infra, carbon, workload, faults,
                        start, ticks, B)
    report("# Eager/scanned parity and the structural-fault fallback")
    parity = parity_run(report, app, infra, carbon, workload, faults,
                        start, ticks, B)
    derate = derate_fallback_run(report, app, infra, carbon, workload,
                                 start, min(ticks, 40), B)

    adaptive = rows["faulty_adaptive"]
    if check:
        assert kinds["node_outage"] >= 3 and kinds["zone_blackout"] >= 1 \
            and kinds["telemetry_dropout"] >= 1, \
            f"fault trace too tame: {kinds}"
        for name, r in rows.items():
            assert r["violations"] == 0, \
                f"{name}: {r['violations']} placement violations"
        assert adaptive["evicted"] > 0, "outages never stranded a service"
        assert adaptive["emergencies"] > 0
        assert adaptive["max_recovery_ticks"] <= MAX_RECOVERY_TICKS, \
            (f"emergency recovery took "
             f"{adaptive['max_recovery_ticks']} ticks")
        assert parity["mismatched_fields"] == 0
        assert parity["saving_close_1e9"]
        assert parity["fallbacks"] == 0
        assert parity["final_assignment_equal"]
        assert parity["violations_eager"] == 0 \
            and parity["violations_scanned"] == 0
        assert derate["fallback_events"] == 1, \
            f"expected exactly one fallback, got {derate}"
        assert derate["reason_is_derate"], derate["reason"]
        assert derate["violations"] == 0

    section = {
        "scenario": {"ticks": ticks, "services": n_services,
                     "nodes": len(node_ids), "scenarios_B": B,
                     "start": start},
        "fault_events": [
            {"kind": e.kind, "target": e.target, "start": e.start,
             "hours": e.hours, "magnitude": e.magnitude}
            for e in faults.events],
        "policies": rows,
        "faulty_vs_fault_free_overhead_g": (
            adaptive["total_emissions_g"]
            - rows["fault_free"]["total_emissions_g"]),
        "oracle_gap_g": (
            adaptive["total_emissions_g"]
            - rows["faulty_oracle"]["total_emissions_g"]),
        "parity": parity,
        "derate_fallback": derate,
    }
    if out_json:
        blob = {}
        if os.path.exists(out_json):
            with open(out_json) as fh:
                blob = json.load(fh)
        blob["fault_recovery"] = section
        with open(out_json, "w") as fh:
            json.dump(blob, fh, indent=2)
        report(f"# merged 'fault_recovery' into {out_json}")
    return section


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI; does not overwrite the "
                         "tracked BENCH json")
    ap.add_argument("--check", action="store_true",
                    help="enforce the recovery/parity/validator gates "
                         "(full runs always check)")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    enable_persistent_cache()
    run(smoke=args.smoke, check=args.check or None,
        out_json=None if (args.no_json or args.smoke) else OUT_JSON)


if __name__ == "__main__":
    main()
