"""Fleet planner scale: 1000 tenants, one shared continuum, one program.

Sweeps the app axis (same per-app shape: S~=50 services, N=200 shared
nodes) through ``repro.fleet.plan_many`` and records:

* **throughput** — warm fleet replan wall time, total and per app, for
  the uncoupled and the waterfill-coupled paths;
* **compile economics** — the entire fleet must run as ONE batched
  program per (backend, bucket-shape) group: cold compiles stay at "a
  handful" (<= ``COMPILE_CEILING``, independent of A) and a warm replan
  touches ZERO new XLA programs (``metrics_scope`` over the planner
  compile cache, ``calls`` must equal ``FleetStats.calls``);
* **capacity soundness** — waterfilling reports zero violated nodes by
  construction, while the same fleet planned uncoupled is allowed (and
  at saturation expected) to over-commit — the delta is what the
  coupling buys;
* **per-tenant billing** — a short ``FleetRuntime`` run over a shared
  carbon trace with the emissions ledger attached: each tenant's billed
  total must equal the plain sum of its runtime-accounted per-tick
  emissions, bitwise.

Merges a ``fleet`` section into ``BENCH_scheduler.json`` (full runs
only) so the scale trajectory is tracked PR-over-PR.

  PYTHONPATH=src python -m benchmarks.fleet_scale [--smoke] [--check]
"""
import argparse
import json
import os
import time

from benchmarks.jax_cache import enable_persistent_cache
from benchmarks.scheduler_scalability import synth

from repro.core.problem import PlacementProblem
from repro.core.scheduler import GreenScheduler, SchedulerConfig
from repro.fleet import FleetProblem, plan_many
from repro.obs import metrics_scope

OUT_JSON = "BENCH_scheduler.json"

# Cold XLA programs for the whole sweep, both coupling modes, all fleet
# sizes: one uncoupled + one waterfill program per bucket-shape group
# (all apps share one group here), NOT one per app or per fleet size.
COMPILE_CEILING = 6


def build_fleet(n_apps, n_services=50, n_nodes=200, seed=0):
    """n_apps distinct problems (varied computation/communication/soft
    constraints) lowered against ONE shared infrastructure."""
    _, infra, _, _, _ = synth(n_services, n_nodes, seed=seed)
    probs = []
    for i in range(n_apps):
        app, _, comp, comm, cs = synth(n_services, n_nodes, seed=seed + 1 + i)
        probs.append(PlacementProblem.build(app, infra, comp, comm, cs))
    return tuple(probs)


def _timed(fn, repeats=1):
    best, out = None, None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best, out


def sweep(report, apps_axis, n_services, n_nodes, sched, repeats, check):
    rows = []
    with metrics_scope() as cold_scope:
        for n_apps in apps_axis:
            t0 = time.perf_counter()
            probs = build_fleet(n_apps, n_services, n_nodes)
            build_s = time.perf_counter() - t0
            names = tuple(f"tenant{i}" for i in range(n_apps))
            prio = tuple(float(n_apps - i) for i in range(n_apps))

            unc = FleetProblem(apps=probs, names=names)
            wf = FleetProblem(apps=probs, names=names, priority=prio,
                              coupling="waterfill")
            plan_many(unc, sched)   # compile warmup: steady state is
            plan_many(wf, sched)    # what the fleet tick replans
            with metrics_scope() as warm:
                t_unc, r_unc = _timed(lambda: plan_many(unc, sched),
                                      repeats)
                t_wf, r_wf = _timed(lambda: plan_many(wf, sched), repeats)
            warm_misses = int(warm.delta("planner.compile.misses"))
            warm_calls = int(warm.delta("planner.compile.calls"))
            expect_calls = repeats * (r_unc.stats.calls + r_wf.stats.calls)

            row = {
                "apps": n_apps, "services": n_services, "nodes": n_nodes,
                "build_s": build_s,
                "uncoupled": {
                    "plan_s": t_unc, "per_app_ms": 1e3 * t_unc / n_apps,
                    "calls": r_unc.stats.calls,
                    "feasible": int(r_unc.feasible.sum()),
                    "violations": r_unc.capacity.violations,
                },
                "waterfill": {
                    "plan_s": t_wf, "per_app_ms": 1e3 * t_wf / n_apps,
                    "calls": r_wf.stats.calls,
                    "feasible": int(r_wf.feasible.sum()),
                    "violations": r_wf.capacity.violations,
                },
                "warm_compile_misses": warm_misses,
            }
            rows.append(row)
            report(f"  A={n_apps:>5}: build {build_s:6.1f}s | "
                   f"uncoupled {t_unc:7.3f}s "
                   f"({row['uncoupled']['per_app_ms']:6.2f}ms/app, "
                   f"{r_unc.capacity.violations} violated nodes) | "
                   f"waterfill {t_wf:7.3f}s "
                   f"({row['waterfill']['per_app_ms']:6.2f}ms/app, "
                   f"{r_wf.capacity.violations} violated, "
                   f"{int(r_wf.feasible.sum())}/{n_apps} feasible)")

            if check:
                assert r_wf.capacity.violations == 0, \
                    "waterfilling over-committed a node"
                assert warm_misses == 0, (
                    f"warm fleet replan recompiled: {warm_misses} misses")
                assert warm_calls == expect_calls, (warm_calls,
                                                    expect_calls)
    cold_compiles = int(cold_scope.delta("planner.compile.misses"))
    report(f"  cold XLA programs across the whole sweep: {cold_compiles} "
           f"(ceiling {COMPILE_CEILING})")
    if check:
        assert cold_compiles <= COMPILE_CEILING, cold_compiles
    return rows, cold_compiles


def billing_run(report, n_tenants, ticks, check):
    """Short fleet-runtime trace with the ledger attached: per-tenant
    bills must decompose the accounted totals bitwise."""
    from repro.continuum import (
        CarbonTrace, REGION_PRESETS, RuntimeConfig, WorkloadTrace)
    from repro.core.types import (
        Application, CommunicationLink, Flavour, FlavourRequirements,
        Infrastructure, Node, NodeCapabilities, Service)
    from repro.fleet import FleetApp, FleetRuntime
    from repro.obs import Observability, billing_report, render_billing

    def tenant_app(tag, n_services):
        services = tuple(
            Service(f"{tag}-svc{i}", flavours=(
                Flavour("large", FlavourRequirements(cpu=2.0, ram_gb=4.0)),
                Flavour("small", FlavourRequirements(cpu=1.0, ram_gb=2.0)),
            )) for i in range(n_services))
        return Application(tag, services,
                           (CommunicationLink(f"{tag}-svc0",
                                              f"{tag}-svc1"),))

    regions = ("solar-south", "wind-north", "coal-east")
    nodes = tuple(
        Node(f"{r}-{k}", region=r, cost_per_cpu_hour=0.5,
             capabilities=NodeCapabilities(cpu=16.0, ram_gb=64.0))
        for r in regions for k in range(3))
    infra = Infrastructure("shared", nodes)
    carbon = CarbonTrace(REGION_PRESETS, hours=ticks + 25, seed=7)
    obs = Observability()
    fas = [FleetApp(f"tenant{i}", tenant_app(f"t{i}", 3 + i % 3),
                    WorkloadTrace(tenant_app(f"t{i}", 3 + i % 3),
                                  seed=i, noise=0.0),
                    priority=float(n_tenants - i))
           for i in range(n_tenants)]
    frt = FleetRuntime(fas, infra, carbon,
                       config=RuntimeConfig(horizon_h=4),
                       coupling="waterfill", obs=obs)
    res = frt.run(0, ticks)
    rep = billing_report(obs.ledger)
    report(render_billing(rep).rstrip("\n"))
    exact = True
    for fa in fas:
        acct = sum(t.emissions_g + t.migration_g
                   for t in res.results[fa.name].ticks)
        exact = exact and rep[fa.name]["total"] == acct
    violations = sum(fr.violations for fr in res.ticks)
    report(f"  {n_tenants} tenants x {ticks} ticks: billed total "
           f"{sum(r['total'] for r in rep.values()):.3f}g, "
           f"bit-exact decomposition: {exact}, "
           f"active-capacity violations: {violations}")
    if check:
        assert exact, "per-tenant bills drifted from accounted emissions"
        assert violations == 0
    return {
        "tenants": n_tenants, "ticks": ticks,
        "bit_exact": exact, "violations": violations,
        "rows": {k: dict(v) for k, v in rep.items()},
    }


def run(report=print, smoke=False, check=None, out_json=OUT_JSON):
    check = True if check is None else check
    if smoke:
        apps_axis, n_services, n_nodes, repeats = (8, 32), 12, 24, 1
        tenants, ticks = 3, 3
    else:
        apps_axis, n_services, n_nodes, repeats = (100, 300, 1000), 50, 200, 2
        tenants, ticks = 5, 6
    # dyadic emission weight + few local-search rounds: the fleet tick
    # replans every app every tick, so steady-state throughput is the
    # honest number (cold compile is counted separately)
    sched = GreenScheduler(SchedulerConfig(
        emission_weight=0.25, local_search_rounds=2))

    report(f"# Fleet scale: apps axis {apps_axis}, S={n_services}, "
           f"N={n_nodes} shared nodes, best of {repeats}")
    rows, cold_compiles = sweep(report, apps_axis, n_services, n_nodes,
                                sched, repeats, check)

    report(f"# Per-tenant billing ({tenants} tenants, {ticks} ticks, "
           "waterfill fleet runtime)")
    billing = billing_run(report, tenants, ticks, check)

    section = {
        "sweep": rows,
        "cold_compiles": cold_compiles,
        "compile_ceiling": COMPILE_CEILING,
        "billing": billing,
    }
    if out_json:
        blob = {}
        if os.path.exists(out_json):
            with open(out_json) as fh:
                blob = json.load(fh)
        blob["fleet"] = section
        with open(out_json, "w") as fh:
            json.dump(blob, fh, indent=2)
        report(f"# merged 'fleet' into {out_json}")
    return section


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet for CI; does not overwrite the "
                         "tracked BENCH json")
    ap.add_argument("--check", action="store_true",
                    help="enforce the capacity/compile/billing gates")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    enable_persistent_cache()
    run(smoke=args.smoke, check=args.check or None,
        out_json=None if (args.no_json or args.smoke) else OUT_JSON)


if __name__ == "__main__":
    main()
