"""Quickstart: the paper's Green-aware Constraint Generator end to end.

Runs the Online Boutique case study (Sect. 5.1): monitoring data ->
energy profiles -> green constraints -> explainability report ->
constraint-aware deployment plan, then one adaptive iteration after a
carbon-intensity shift (Scenario 3).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import boutique
from repro.core.pipeline import GreenConstraintPipeline
from repro.core.scheduler import GreenScheduler, SchedulerConfig, plan_emissions


def emissions_of(plan, app, infra, comp, comm):
    assign = {p.service: (p.flavour, p.node) for p in plan.placements}
    return plan_emissions(app, infra, assign, comp, comm)


def main():
    # ---- iteration 1: Scenario 1 (Europe) --------------------------------
    app, infra, mon = boutique.scenario(1)
    pipe = GreenConstraintPipeline()
    out = pipe.run(app, infra, mon)

    print("=== Green-aware constraints (Prolog dialect) ===")
    print(out.prolog)
    print("\n=== Explainability Report (first entry) ===")
    print(out.report.entries[0])

    # one PlacementProblem per iteration — the single planner input; both
    # scheduler profiles share it (and its cached lowering)
    problem = pipe.problem_for(out)
    app_e, infra_e = out.app, out.infra
    comp, comm = out.computation, out.communication
    green = GreenScheduler(SchedulerConfig.green()).plan(problem).plan
    base = GreenScheduler(SchedulerConfig.baseline()).plan(problem).plan
    e_g = emissions_of(green, app_e, infra_e, comp, comm)
    e_b = emissions_of(base, app_e, infra_e, comp, comm)
    print("\n=== Deployment plan (green) ===")
    for p in green.placements:
        print(f"  {p.service:<16} [{p.flavour:<6}] -> {p.node}")
    print(f"\nemissions: baseline {e_b:.0f} g -> green {e_g:.0f} g "
          f"({100 * (1 - e_g / e_b):.1f}% saved)")

    # ---- iteration 2: France degrades (Scenario 3) ------------------------
    app3, infra3, mon3 = boutique.scenario(3)
    out3 = pipe.run(app3, infra3, mon3)  # same pipeline: KB carries over
    print("\n=== After carbon shift (France 16 -> 376 gCO2eq/kWh) ===")
    print(out3.prolog)


if __name__ == "__main__":
    main()
