"""Green deployment of TPU jobs across pods (the beyond-paper layer).

Takes the dry-run roofline records of real (arch x shape) cells as the
monitoring source, derives AvoidNode/Affinity constraints with the SAME
pipeline the paper uses for microservices, and places jobs onto pods in
regions with different carbon intensities.  The disaggregated
prefill/decode pair exchanging KV caches demonstrates the Affinity path:
its traffic must stay on ICI (same pod), not DCN.

  PYTHONPATH=src python examples/green_deployment.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.green_placement import (
    GreenPlacement,
    JobSpec,
    PodSpec,
    TrafficSpec,
)

DRYRUN = os.path.join(os.path.dirname(__file__), "..",
                      "dryrun_results.jsonl")

# Bundled fallback profiles (from a committed dry-run of this repo) so the
# example runs before a local dry-run exists.
FALLBACK = {
    ("yi-9b", "train_4k"): {
        "compute_s": 1.22, "memory_s": 8.51, "collective_s": 3.86},
    ("yi-9b", "prefill_32k"): {
        "compute_s": 0.37, "memory_s": 2.50, "collective_s": 1.15},
    ("yi-9b", "decode_32k"): {
        "compute_s": 0.0003, "memory_s": 0.035, "collective_s": 0.003},
    ("granite-moe-3b-a800m", "train_4k"): {
        "compute_s": 0.22, "memory_s": 6.00, "collective_s": 1.40},
    ("falcon-mamba-7b", "long_500k"): {
        "compute_s": 0.0001, "memory_s": 0.015, "collective_s": 0.0002},
}


def roofline_lookup():
    table = dict(FALLBACK)
    if os.path.exists(DRYRUN):
        for line in open(DRYRUN):
            r = json.loads(line)
            if r.get("status") == "ok" and not r["multi_pod"]:
                f = r["roofline"]
                table[(r["arch"], r["shape"])] = {
                    "compute_s": f["compute_s"],
                    "memory_s": f["memory_s"],
                    "collective_s": f["collective_s"],
                }
    return table


def main():
    roof = roofline_lookup()

    def flavours(arch, shape, scale_eco=0.55):
        """'perf' = the measured cell; 'eco' = a reduced-clock/precision
        flavour trading throughput for energy (SADP-style flavour)."""
        base = roof[(arch, shape)]
        return {
            "perf": base,
            "eco": {k: v * scale_eco for k, v in base.items()},
        }

    jobs = [
        JobSpec("yi9b-train", "yi-9b", "train_4k",
                flavours("yi-9b", "train_4k"),
                flavours_order=("perf", "eco"), delay_tolerance_h=12),
        JobSpec("granite-train", "granite-moe-3b-a800m", "train_4k",
                flavours("granite-moe-3b-a800m", "train_4k"),
                flavours_order=("perf", "eco"), delay_tolerance_h=12),
        JobSpec("yi9b-prefill", "yi-9b", "prefill_32k",
                flavours("yi-9b", "prefill_32k"), steps_per_h=900.0),
        JobSpec("yi9b-decode", "yi-9b", "decode_32k",
                flavours("yi-9b", "decode_32k"), steps_per_h=3.6e6),
        JobSpec("mamba-long", "falcon-mamba-7b", "long_500k",
                flavours("falcon-mamba-7b", "long_500k"),
                steps_per_h=3.6e6, must_deploy=False),
    ]
    # prefill -> decode KV-cache handoff: a 32k cache of yi-9b is ~8 GB;
    # at ~900 prefills/h that is ~7 TB/h of traffic if split across pods.
    # Checkpoint cross-replication between the train jobs is light by
    # comparison — it should NOT trigger an Affinity constraint.
    traffic = [
        TrafficSpec("yi9b-prefill", "yi9b-decode", gb_per_h=7200.0),
        TrafficSpec("yi9b-train", "granite-train", gb_per_h=60.0),
    ]

    # texas: solar-heavy grid — dirty now, clean around midday (+6h).
    tx_forecast = (410.0, 390.0, 340.0, 260.0, 180.0, 130.0, 110.0,
                   140.0, 220.0, 320.0, 400.0, 420.0, 430.0)
    pods = [
        PodSpec("pod-fi", "finland", carbon=80.0, cost_per_chip_hour=1.1),
        PodSpec("pod-fr", "france", carbon=16.0, cost_per_chip_hour=1.3),
        PodSpec("pod-ie", "ireland", carbon=290.0, cost_per_chip_hour=1.0),
        PodSpec("pod-va", "virginia", carbon=350.0, cost_per_chip_hour=0.9),
        PodSpec("pod-tx", "texas", carbon=410.0, cost_per_chip_hour=0.8,
                carbon_forecast=tx_forecast),
    ]

    plan, out, stats = GreenPlacement().place(jobs, pods, traffic)

    print("=== Green-aware constraints over the TPU fleet ===")
    print(out.prolog)
    print("\n=== Job placement ===")
    for p in plan.placements:
        print(f"  {p.service:<14} [{p.flavour}] -> {p.node}")
    if plan.skipped_services:
        print(f"  skipped optional: {plan.skipped_services}")
    co = {p.service: p.node for p in plan.placements}
    same = co.get("yi9b-prefill") == co.get("yi9b-decode")
    print(f"\nprefill/decode co-located (KV on ICI): {same}")
    print(f"emissions: baseline {stats['baseline_g_per_window']:.0f} g "
          f"-> green {stats['green_g_per_window']:.0f} g "
          f"({100 * stats['saved_frac']:.1f}% saved)")
    shifts = [c for c in out.constraints if c.kind == "timeShift"]
    for c in shifts:
        print(f"timeShift: postpone {c.service} on {c.node} by "
              f"{c.shift_h}h (w={c.weight:.2f})")
    assert same, "affinity constraint must keep the KV handoff on-pod"
    assert shifts, "delay-tolerant train jobs on a solar grid must " \
                   "produce TimeShift suggestions"


if __name__ == "__main__":
    main()
