"""Elastic failover: losing a pod re-plans placement through the SAME
green scheduler used at launch — fault handling and carbon-awareness share
one decision mechanism (DESIGN.md §8).

Timeline simulated here with a real (reduced) training loop:
  1. green placement assigns the train job across a 3-pod fleet;
  2. training runs with atomic checkpoints;
  3. the hosting pod FAILS mid-run: plan_elastic_mesh() re-plans the
     device mesh for the survivors, green placement re-runs WITHOUT the
     lost pod, and the job resumes from the last complete checkpoint with
     the data pipeline re-sharded — bit-identical continuation;
  4. the re-placement still avoids the dirty pod.

  PYTHONPATH=src python examples/elastic_failover.py
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data.pipeline import DataConfig, batch_for_step
from repro.ft.manager import RestartManager, plan_elastic_mesh
from repro.launch.green_placement import GreenPlacement, JobSpec, PodSpec
from repro.models.config import CellTuning
from repro.models.schema import build_schema
from repro.models.sharding import init_from_schema
from repro.models.testing import reduced
from repro.optim import adamw
from repro.train.steps import make_train_step

CKPT = "/tmp/repro_elastic_demo"
ROOF = {"perf": {"compute_s": 1.2, "memory_s": 8.5, "collective_s": 3.9}}


def place(pods):
    job = JobSpec("train-job", "qwen2-1.5b", "train_4k", ROOF,
                  delay_tolerance_h=12)
    plan, out, stats = GreenPlacement().place([job], pods)
    assert plan.feasible
    return plan.node_of("train-job")


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    pods = [
        PodSpec("pod-a", "finland", carbon=80.0, cost_per_chip_hour=1.0),
        PodSpec("pod-b", "france", carbon=16.0, cost_per_chip_hour=1.3),
        PodSpec("pod-dirty", "texas", carbon=410.0, cost_per_chip_hour=0.7),
    ]
    home = place(pods)
    print(f"[t0] green placement: train-job -> {home} "
          f"(cheapest pod is pod-dirty; the green scheduler pays more)")
    assert home != "pod-dirty"

    # --- the training job itself (reduced twin, real steps) ---------------
    cfg = reduced(get_arch("qwen2-1.5b"))
    opt_cfg = adamw.OptimizerConfig(lr=1e-2, warmup_steps=5, decay_steps=200)
    tuning = CellTuning(num_microbatches=1, remat=False,
                        compute_dtype="float32")
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, tuning))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=11)

    def init_fn():
        params = init_from_schema(jax.random.PRNGKey(11),
                                  build_schema(cfg), jnp.float32)
        return {"params": params, "opt": adamw.init(opt_cfg, params)}

    losses = []

    def make_step(n_shards):
        def train_one(state, step):
            # every shard produced independently, then concatenated — the
            # stream is identical for ANY shard count (elasticity)
            parts = [batch_for_step(dcfg, step, shard=(i, n_shards))
                     for i in range(n_shards)]
            batch = {k: jnp.asarray(np.concatenate([p[k] for p in parts]))
                     for k in parts[0]}
            params, opt, m = step_fn(state["params"], state["opt"], batch)
            losses.append(float(m["loss"]))
            return {"params": params, "opt": opt}
        return train_one

    mgr = RestartManager(CKPT, checkpoint_every=10)
    mgr.run(init_fn, make_step(n_shards=2), num_steps=25)
    print(f"[t1] trained 25 steps on {home} (2 data shards), "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; checkpoint at step 25")

    # --- pod failure --------------------------------------------------------
    print(f"[t2] {home} FAILS. survivors re-mesh + re-place:")
    survivors = [p for p in pods if p.pod_id != home]
    mesh_plan = plan_elastic_mesh(256 * len(survivors), model=16)
    print(f"     elastic mesh for {256 * len(survivors)} chips: "
          f"(pod, data, model) = {mesh_plan}")
    new_home = place(survivors)
    print(f"     green re-placement: train-job -> {new_home}")
    assert new_home != "pod-dirty" and new_home != home

    # --- resume: one surviving data shard, same stream ----------------------
    mgr2 = RestartManager(CKPT, checkpoint_every=10)
    state, start = mgr2.resume_or_init(init_fn)
    print(f"[t3] resumed from step {start} on {new_home} "
          f"(re-sharded to 1 shard)")
    assert start == 25
    mgr2.run(init_fn, make_step(n_shards=1), num_steps=40)
    print(f"[t4] finished 40 steps, final loss {losses[-1]:.3f} "
          f"(continued the SAME deterministic stream)")
    assert losses[-1] < losses[0]
    print("elastic failover: OK")


if __name__ == "__main__":
    main()
