"""Batched serving example, two modes:

  1. lockstep: prefill a batch of prompts, decode together — across three
     architecture families (attention, SSM, hybrid), one serving API;
  2. continuous batching: a slot-pool engine admits queued requests of
     different lengths mid-stream, every tick decodes all occupied slots at
     their OWN positions, finished requests free slots immediately.

  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.launch.serve import serve_batch
from repro.models.schema import build_schema
from repro.models.sharding import init_from_schema
from repro.models.testing import reduced


def continuous_batching_demo():
    import numpy as np

    from repro.serve import Request, ServeEngine

    cfg = reduced(get_arch("qwen2-1.5b"))
    params = init_from_schema(jax.random.PRNGKey(0),
                              build_schema(cfg), jnp.float32)
    engine = ServeEngine(cfg, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for i in range(5):  # 5 requests, varied lengths, only 2 slots
        engine.submit(Request(
            i, rng.integers(0, cfg.vocab, size=int(rng.integers(6, 20))),
            max_new_tokens=int(rng.integers(3, 8))))
    stats = engine.run_until_drained()
    print(f"continuous batching: {stats.finished} requests through "
          f"{engine.slots} slots in {stats.ticks} ticks "
          f"({stats.occupancy_tokens_per_tick:.2f} tok/tick; "
          f"serial would need {stats.decoded_tokens} ticks)")


def main():
    for arch in ("qwen2-1.5b", "falcon-mamba-7b", "zamba2-1.2b"):
        cfg = reduced(get_arch(arch))
        params = init_from_schema(jax.random.PRNGKey(0),
                                  build_schema(cfg), jnp.float32)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 24),
                                     0, cfg.vocab)
        t0 = time.perf_counter()
        seqs = serve_batch(cfg, params, prompts, gen_tokens=12)
        dt = time.perf_counter() - t0
        assert seqs.shape == (4, 36)
        print(f"{arch:<18} ({cfg.family.value:<7}) "
              f"4 prompts x 24 tok -> +12 tok each in {dt:5.1f}s "
              f"| continuation[0]: {list(map(int, seqs[0, 24:28]))}...")
    continuous_batching_demo()


if __name__ == "__main__":
    main()
