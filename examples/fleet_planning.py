"""Fleet planning: many tenants, one shared green continuum.

Builds a small multi-tenant fleet — several applications, each with its
own workload trace and priority, competing for ONE infrastructure — and
shows the three capacity-coupling modes of ``repro.fleet.plan_many``:

* ``"none"``      — every tenant sees the full capacity (bit-identical
  to per-app ``plan`` calls); over-commit is reported, not prevented;
* ``"waterfill"`` — tenants plan in priority order against the capacity
  the higher-priority tenants left behind (never over-commits);
* ``"price"``     — per-node shadow prices steer the fully parallel
  batched program away from contested nodes.

Then drives the whole fleet through a day of the adaptive continuum
loop (``FleetRuntime``: one batched replan per tick, per-app hysteresis)
with the emissions ledger attached, and prints each tenant's carbon
bill — whose totals decompose the fleet's accounted emissions exactly.

  PYTHONPATH=src python examples/fleet_planning.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.continuum import (
    CarbonTrace,
    REGION_PRESETS,
    RuntimeConfig,
    WorkloadTrace,
)
from repro.core.problem import PlacementProblem
from repro.core.scheduler import GreenScheduler, SchedulerConfig
from repro.core.types import (
    Application,
    CommunicationLink,
    Flavour,
    FlavourRequirements,
    Infrastructure,
    Node,
    NodeCapabilities,
    Service,
)
from repro.fleet import FleetApp, FleetProblem, FleetRuntime, plan_many
from repro.obs import Observability, billing_report, render_billing


def tenant_app(tag: str, n_services: int) -> Application:
    services = tuple(
        Service(f"{tag}-svc{i}", flavours=(
            Flavour("large", FlavourRequirements(cpu=2.0, ram_gb=4.0)),
            Flavour("small", FlavourRequirements(cpu=1.0, ram_gb=2.0)),
        )) for i in range(n_services))
    links = (CommunicationLink(f"{tag}-svc0", f"{tag}-svc1"),)
    return Application(tag, services, links)


def shared_infra(carbon_by_region=None) -> Infrastructure:
    regions = ("solar-south", "wind-north", "coal-east")
    nodes = tuple(
        Node(f"{r}-{k}", region=r, cost_per_cpu_hour=0.5,
             carbon=(carbon_by_region or {}).get(r),
             capabilities=NodeCapabilities(cpu=8.0, ram_gb=32.0))
        for r in regions for k in range(2))
    return Infrastructure("continuum", nodes)


def main() -> None:
    infra = shared_infra()
    carbon = CarbonTrace(REGION_PRESETS, hours=48, seed=11)
    sched = GreenScheduler(SchedulerConfig(emission_weight=1.0))

    # -- one-shot: the three coupling modes on the same fleet ---------
    # (static per-region carbon for the one-shot; the runtime below
    # gets the live trace through the constraint pipeline instead)
    apps = {f"tenant{i}": tenant_app(f"t{i}", 3 + i) for i in range(4)}
    static = shared_infra({"solar-south": 80.0, "wind-north": 120.0,
                           "coal-east": 520.0})
    probs = tuple(
        PlacementProblem.build(
            app, static,
            {(s.component_id, f.name): 20.0 * f.requirements.cpu
             for s in app.services for f in s.flavours},
            {}, [])
        for app in apps.values())
    names = tuple(apps)
    prio = tuple(float(len(apps) - i) for i in range(len(apps)))
    print("== one-shot plan_many, three coupling modes ==")
    for coupling in ("none", "waterfill", "price"):
        fleet = FleetProblem(apps=probs, names=names, priority=prio,
                             coupling=coupling)
        res = plan_many(fleet, sched)
        feas = int(res.feasible.sum())
        print(f"  {coupling:<10} feasible {feas}/{len(fleet)}, "
              f"violated nodes {res.capacity.violations}, "
              f"total {res.total_emissions_g:10.2f} g, "
              f"{res.stats.calls} program call(s)")

    # -- a day of the fleet's adaptive loop, billed per tenant --------
    print("\n== 24 ticks of FleetRuntime (waterfill) ==")
    obs = Observability()
    fas = [FleetApp(name, tenant_app(f"t{i}", 3 + i),
                    WorkloadTrace(tenant_app(f"t{i}", 3 + i),
                                  seed=i, noise=0.0),
                    priority=float(len(apps) - i))
           for i, name in enumerate(apps)]
    frt = FleetRuntime(fas, infra, carbon, config=RuntimeConfig(),
                       coupling="waterfill", obs=obs)
    res = frt.run(0, 24)
    s = res.summary()
    print(f"  {s['apps']:.0f} tenants, {s['ticks']:.0f} ticks: "
          f"{s['total_emissions_g']:.1f} g total, "
          f"{s['switches']:.0f} switches, "
          f"{s['violations']:.0f} capacity violations")
    print("\n== per-tenant carbon bill ==")
    print(render_billing(billing_report(obs.ledger)), end="")


if __name__ == "__main__":
    main()
