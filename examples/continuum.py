"""Continuum adaptive loop: a microservice app following the sun.

Runs the ContinuumRuntime for three simulated days over synthetic regional
carbon traces (solar/wind/hydro/coal archetypes): each hour the pipeline
re-estimates energy profiles, refreshes the KB-ranked constraints, prices
a forecast ensemble in one batched jit/vmap call, and relocates services
only when the expected saving beats the migration cost — then prints the
per-day emissions of the adaptive loop next to a plan-once baseline.

  PYTHONPATH=src python examples/continuum.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.continuum import (
    CarbonTrace,
    ContinuumRuntime,
    REGION_PRESETS,
    RuntimeConfig,
    WhatIfPlanner,
    WorkloadTrace,
)
from repro.core.scheduler import GreenScheduler, SchedulerConfig
from repro.core.types import (
    Application,
    CommunicationLink,
    Flavour,
    FlavourRequirements,
    Infrastructure,
    Node,
    NodeCapabilities,
    Service,
)

START, DAYS = 24, 3


def build_app():
    services = tuple(
        Service(f"svc{i}", flavours=(
            Flavour("large", FlavourRequirements(cpu=2.0, ram_gb=4.0)),
            Flavour("small", FlavourRequirements(cpu=1.0, ram_gb=2.0)),
        )) for i in range(8))
    links = (CommunicationLink("svc0", "svc1"),
             CommunicationLink("svc2", "svc3"))
    return Application("continuum-demo", services, links)


def build_infra():
    nodes = tuple(
        Node(f"{region}-{k}", region=region, cost_per_cpu_hour=0.5,
             capabilities=NodeCapabilities(cpu=4.0, ram_gb=16.0))
        for region in ("solar-south", "wind-north", "coal-east")
        for k in range(2))
    return Infrastructure("continuum-demo", nodes)


def run_policy(app, infra, carbon, workload, config):
    runtime = ContinuumRuntime(
        app, infra, carbon, workload, config=config,
        planner=WhatIfPlanner(
            GreenScheduler(SchedulerConfig(emission_weight=1.0))))
    return runtime.run(start=START, ticks=DAYS * 24)


def main():
    app, infra = build_app(), build_infra()
    carbon = CarbonTrace(REGION_PRESETS, hours=START + DAYS * 24 + 25,
                         seed=42)
    workload = WorkloadTrace(app, seed=42)

    adaptive = run_policy(app, infra, carbon, workload,
                          RuntimeConfig(scenarios=8, hysteresis_g=30.0))
    static = run_policy(app, infra, carbon, workload,
                        RuntimeConfig(replan_every=10 ** 9))

    print(f"{'day':>4} {'adaptive_g':>11} {'static_g':>9}")
    for d in range(DAYS):
        a = sum(r.emissions_g + r.migration_g
                for r in adaptive.ticks[d * 24:(d + 1) * 24])
        s = sum(r.emissions_g for r in static.ticks[d * 24:(d + 1) * 24])
        print(f"{d:>4} {a:>11.1f} {s:>9.1f}")
    a, s = adaptive.total_emissions_g, static.total_emissions_g
    print(f"\nadaptive: {a:.1f} g ({adaptive.total_migrations} migrations)"
          f"  static: {s:.1f} g  ->  saved {1 - a / s:.1%}")
    print("\nfinal adaptive assignment:")
    for sid, (fl, node) in sorted(adaptive.final_assignment.items()):
        print(f"  {sid:>6} -> {node} ({fl})")


if __name__ == "__main__":
    main()
