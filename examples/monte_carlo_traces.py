"""Monte Carlo over whole adaptive traces: one vmap'd megaloop call.

The scanned continuum loop stages a trace once and rolls it with a
single ``jit(lax.scan)``; ``monte_carlo_emissions`` then ``vmap``s that
program over a batch of carbon realities (multiplicative perturbations
of the recorded/forecast carbon intensity).  Every sample replays the
FULL adaptive loop — replanning, hysteresis, switching, migration
charges — under its own carbon world, so the spread is the real
sensitivity of the closed-loop system, not of a frozen plan.

Prints the emissions distribution of a 2-day trace under ±30% carbon
scenarios, next to the deterministic (scale = 1.0) trace.  With
``--dump PATH`` the deterministic trace is also rolled once (fused
scan, full observability) and written as a ContinuumResult JSONL that
``benchmarks.make_tables`` renders into a green-audit section.

  PYTHONPATH=src python examples/monte_carlo_traces.py [--dump PATH]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.continuum import (
    CarbonTrace,
    ContinuumRuntime,
    REGION_PRESETS,
    RuntimeConfig,
    WhatIfPlanner,
    WorkloadTrace,
)
from repro.continuum.megaloop import monte_carlo_emissions
from repro.core.scheduler import GreenScheduler, SchedulerConfig
from repro.core.types import (
    Application,
    CommunicationLink,
    Flavour,
    FlavourRequirements,
    Infrastructure,
    Node,
    NodeCapabilities,
    Service,
)

START, TICKS = 24, 48


def build():
    services = tuple(
        Service(f"svc{i}", flavours=(
            Flavour("large", FlavourRequirements(cpu=2.0, ram_gb=4.0)),
            Flavour("small", FlavourRequirements(cpu=1.0, ram_gb=2.0)),
        )) for i in range(10))
    links = tuple(CommunicationLink(f"svc{i}", f"svc{(i + 1) % 10}")
                  for i in range(0, 10, 2))
    app = Application("mc-demo", services, links)
    nodes = tuple(
        Node(f"{r}-{k}", region=r, cost_per_cpu_hour=0.5,
             capabilities=NodeCapabilities(cpu=5.0, ram_gb=24.0))
        for r in ("solar-south", "wind-north", "coal-east")
        for k in range(2))
    return app, Infrastructure("mc-demo", nodes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dump", metavar="PATH", default=None,
                    help="write the deterministic trace as a "
                         "ContinuumResult JSONL (continuum-result/v1)")
    args = ap.parse_args()
    app, infra = build()
    runtime = ContinuumRuntime(
        app, infra,
        CarbonTrace(REGION_PRESETS, hours=START + TICKS + 25, seed=0),
        WorkloadTrace(app, seed=0),
        config=RuntimeConfig(scenarios=4, hysteresis_g=30.0),
        planner=WhatIfPlanner(
            GreenScheduler(SchedulerConfig(emission_weight=1.0))))

    # 21 carbon realities from 30% cleaner to 30% dirtier, one vmap call
    scales = np.linspace(0.7, 1.3, 21)
    totals, per_tick = monte_carlo_emissions(
        runtime, START, TICKS, ci_scales=scales)

    det = totals[np.argmin(np.abs(scales - 1.0))]
    print(f"# {len(scales)} carbon realities x {TICKS} ticks "
          f"(one vmap(jit(lax.scan)) call)")
    print(f"deterministic trace : {det:10.1f} gCO2eq")
    print(f"mean / std          : {totals.mean():10.1f} / "
          f"{totals.std():.1f} gCO2eq")
    print(f"p05 .. p95          : {np.percentile(totals, 5):10.1f} .. "
          f"{np.percentile(totals, 95):.1f} gCO2eq")
    # the adaptive loop is sub-linear in carbon scale: when the whole
    # grid gets dirtier it shifts more load to the cleanest regions
    lo, hi = totals[0], totals[-1]
    print(f"0.7x / 1.3x carbon  : {lo:10.1f} / {hi:.1f} gCO2eq "
          f"({hi / det - 1.0:+.1%} at +30% CI)")
    worst = per_tick.max(axis=0)
    print(f"worst-case tick     : {worst.max():10.1f} gCO2eq "
          f"(tick {int(worst.argmax())})")

    if args.dump:
        from repro.obs import Observability
        runtime.obs = Observability()
        result = runtime.run_scanned(START, TICKS)
        result.to_jsonl(args.dump)
        print(f"wrote {args.dump} ({len(result.ticks)} ticks, "
              f"schema continuum-result/v1)")


if __name__ == "__main__":
    main()
