"""End-to-end training driver: a ~100M-parameter dense LM trained for a
few hundred steps on the synthetic pipeline, with fault-tolerant
checkpointing (kill and re-run: it resumes).

A ~100M model at a few hundred steps is hours of CPU time; the default
here is a faithful-but-smaller ~27M twin at 300 steps (~15 min).  Pass
``--hundred-m`` for the full-size run, or tune the flags.

  PYTHONPATH=src python examples/train_100m.py [--hundred-m] [--steps N]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data.pipeline import DataConfig, batch_for_step
from repro.ft.manager import RestartManager
from repro.models.config import CellTuning
from repro.models.schema import build_schema
from repro.models.sharding import init_from_schema
from repro.optim import adamw
from repro.train.steps import make_train_step


def model_config(hundred_m: bool):
    base = get_arch("qwen2-1.5b")  # dense GQA family
    if hundred_m:
        # ~103M params: 12L x 768, 12 heads (GQA 4 kv), ff 3072, vocab 16384
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=3072, vocab=16384, head_dim=64)
    # ~27M params: 8L x 384, ff 1536, vocab 8192
    return dataclasses.replace(
        base, n_layers=8, d_model=384, n_heads=8, n_kv_heads=4,
        d_ff=1536, vocab=8192, head_dim=48)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = model_config(args.hundred_m)
    print(f"model: {cfg.n_layers}L x {cfg.d_model} "
          f"(~{cfg.param_count() / 1e6:.0f}M params), "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq_len}",
          flush=True)

    tuning = CellTuning(num_microbatches=2, remat=True,
                        compute_dtype="float32")
    opt_cfg = adamw.OptimizerConfig(lr=1e-2, warmup_steps=10,
                                    decay_steps=max(3 * args.steps, 300))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, tuning))
    # data vocab smaller than the model's: at a few hundred steps every
    # token needs enough observations for the LCG structure to be learnable
    dcfg = DataConfig(vocab=min(2048, cfg.vocab), seq_len=args.seq_len,
                      global_batch=args.batch, seed=7)

    def init_fn():
        params = init_from_schema(jax.random.PRNGKey(7),
                                  build_schema(cfg), jnp.float32)
        return {"params": params, "opt": adamw.init(opt_cfg, params)}

    losses = []

    def train_one(state, step):
        batch = {k: jnp.asarray(v)
                 for k, v in batch_for_step(dcfg, step).items()}
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % 20 == 0:
            print(f"step {step + 1:>4}  loss {losses[-1]:.4f}", flush=True)
        return {"params": params, "opt": opt}

    mgr = RestartManager(args.ckpt_dir, checkpoint_every=50)
    mgr.run(init_fn, train_one, num_steps=args.steps)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'OK: learning' if last < first - 0.3 else 'WARN: flat'})")
    print(f"checkpoints in {args.ckpt_dir} (re-run to resume)")


if __name__ == "__main__":
    main()
